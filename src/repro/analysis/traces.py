"""Trace builders: registry config -> compiled hot-path :class:`Trace`s.

Everything here is ABSTRACT (``jax.ShapeDtypeStruct`` leaves via
``recipe.abstract_quantize`` + ``launch.specs``): no weights are
materialized, so sweeping the whole registry is a compile-only operation —
the same AOT path the multi-pod dry-run uses.

Kernel dispatch is scoped ON around lowering (``ops.dispatch``): dispatch
resolves at trace time, and the qlint invariants are claims about the
KERNEL hot path (the interpret-mode Pallas bodies trace into real HLO on
CPU, so integer dots/converts are visible in the lowered text).
"""
from __future__ import annotations

import contextlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax

from ..configs.registry import REDUCED
from ..kernels import ops
from ..models import get_model
from ..recipe import abstract_quantize, _resolve_cfg
from ..launch.specs import decode_inputs, prefill_inputs
from .rules import Trace


def _path_str(keypath) -> str:
    parts = []
    for k in keypath:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


_HLO_DT = {"float32": "f32", "float64": "f64", "float16": "f16",
           "bfloat16": "bf16", "int8": "s8", "int16": "s16", "int32": "s32",
           "int64": "s64", "uint8": "u8", "uint16": "u16", "uint32": "u32",
           "uint64": "u64", "bool": "pred", "int4": "s4", "uint4": "u4"}


def param_paths(args) -> List[str]:
    leaves = jax.tree_util.tree_flatten_with_path(args)[0]
    return [_path_str(kp) for kp, _ in leaves]


def param_leaves(args) -> List[Tuple[str, str, List[int]]]:
    """(path, hlo dtype, shape) per flattened argument leaf — what
    Trace.param_path aligns against the surviving entry parameters."""
    leaves = jax.tree_util.tree_flatten_with_path(args)[0]
    return [(_path_str(kp), _HLO_DT.get(str(leaf.dtype), str(leaf.dtype)),
             list(leaf.shape))
            for kp, leaf in leaves]


def trace_fn(fn, args, *, name: str, meta: Optional[dict] = None,
             in_shardings=None, dispatch: Optional[bool] = True) -> Trace:
    """Lower + compile ``fn(*args)`` (abstract args welcome) and wrap the
    optimized HLO in a :class:`Trace`.  ``dispatch``: True/False scopes
    all three kernel-dispatch axes on/off around lowering; None inherits
    the ambient scope (inner ``ops.dispatch`` scopes inside ``fn`` always
    win either way)."""
    jit_kw = {}
    if in_shardings is not None:
        jit_kw["in_shardings"] = in_shardings
    jf = jax.jit(fn, **jit_kw)
    scope = (contextlib.nullcontext() if dispatch is None
             else ops.dispatch(dense=dispatch, conv=dispatch, attn=dispatch))
    with scope:
        compiled = jf.lower(*args).compile()
    m = dict(meta or {})
    m.setdefault("param_paths", param_paths(args))
    m.setdefault("param_leaves", param_leaves(args))
    return Trace(name=name, text=compiled.as_text(), meta=m,
                 compiled=compiled)


def _resolve_reduced(arch: str):
    if arch in REDUCED:
        return REDUCED[arch]
    return _resolve_cfg(arch)  # full-size / already-reduced names


def _int8_kv_cfg(cfg):
    """The int8-KV flavor of ``cfg`` when its cache honors it, else None."""
    if cfg.family == "efficientvit":
        return None
    try:
        cfg8 = cfg.replace(kv_cache_dtype="int8")
        model = get_model(cfg8)
        cache = jax.eval_shape(lambda: model.init_cache(cfg8, 2, 16))
        if any(getattr(l, "dtype", None) == jax.numpy.int8
               for l in jax.tree.leaves(cache)):
            return cfg8
    except (TypeError, ValueError):
        pass
    return None


# extra input resolutions traced for vision configs under the m2q recipe
# (batch 1 — the latency-bound serving shape).  The stem is stride-2, so
# R384/R512 inputs put 192x192 and 256x256 maps through every depthwise
# layer: both beyond the old whole-map VMEM guard, and the conv-budget
# rule holds the H-tiled kernel to ZERO XLA fallback convolutions there.
VISION_HIRES: Tuple[int, ...] = (384, 512)


def registry_trace_specs(arch: str, *, batch: int = 2, prefill_len: int = 32,
                         decode_len: int = 64,
                         recipes: Sequence[str] = ("m2q-w8a8", "uniform8"),
                         hires: Sequence[int] = VISION_HIRES):
    """Yield ``(name, fn, args, meta)`` for one registry config (reduced
    shapes) — the shared hot-path enumeration behind :func:`registry_traces`
    (lower+compile for qlint) and :func:`shape_requests` (lower-only
    autotune shape discovery).

    Vision configs trace ``forward`` at the config resolution plus each
    ``hires`` resolution (m2q recipe only); token configs trace prefill and
    decode (with the int8-KV cache when the family supports it — the
    fully-quantized serving posture is exactly where the laundering rules
    matter).  Each recipe gets its own trace set; ``uniform8`` traces
    additionally promise ``expect_no_f32_dot`` (the M2Q APoT half keeps a
    by-design f32 SAT-engine dot, so only the uniform recipe can make
    that promise).
    """
    cfg = _resolve_reduced(arch)
    model = get_model(cfg)
    for recipe in recipes:
        rtag = {"m2q-w8a8": "m2q", "uniform8": "u8"}.get(recipe, recipe)
        no_f32 = recipe == "uniform8"
        if cfg.family == "efficientvit":
            # conv budget: only the unquantized stem convolves under m2q
            # (PWConvs lower to quantized matmuls, DWConvs to the packed-w4
            # kernel); uniform8 has no int8 DWConv kernel, so its DWConvs
            # legitimately fall back to dequantized XLA convs — no budget
            variants = [(cfg.img_res, batch, f"{arch}/{rtag}/forward")]
            if recipe == "m2q-w8a8":
                variants += [(r, 1, f"{arch}/{rtag}/forward-r{r}")
                             for r in hires]
            for res, b, name in variants:
                cfg_v = cfg if res == cfg.img_res else cfg.replace(img_res=res)
                qp = abstract_quantize(cfg_v, recipe=recipe,
                                       tokens_per_step=b)
                imgs = jax.ShapeDtypeStruct(
                    (b, res, res, 3), jax.numpy.float32)

                def fwd(p, x, _cfg=cfg_v, _model=model):
                    return _model.forward(_cfg, p, x)

                yield (fwd, (qp, imgs), name,
                       {"quantized": True, "expect_no_f32_dot": no_f32,
                        "conv_budget": 1 if recipe == "m2q-w8a8" else None})
            continue
        cfg8 = _int8_kv_cfg(cfg)
        cfg_t = cfg8 or cfg
        model_t = get_model(cfg_t)
        tps_prefill = batch * prefill_len
        qp = abstract_quantize(cfg_t, recipe=recipe,
                               tokens_per_step=tps_prefill)
        inp, cache = prefill_inputs(cfg_t, batch, prefill_len)

        def prefill(p, c, i, _cfg=cfg_t, _model=model_t):
            return _model.prefill(_cfg, p, c, **i)

        # LM prefill attention runs f32 score/value dots by design (the
        # int8 attention kernels cover MSA + int8-KV decode), so only the
        # decode trace can promise zero f32 dots — and only with the
        # int8-KV cache + uniform weights
        yield (prefill, (qp, cache, inp), f"{arch}/{rtag}/prefill",
               {"quantized": True})

        qp_d = abstract_quantize(cfg_t, recipe=recipe, tokens_per_step=batch)
        dcache, dtok = decode_inputs(cfg_t, batch, decode_len)

        def decode(p, c, t, _cfg=cfg_t, _model=model_t):
            return _model.decode_step(_cfg, p, c, t)

        yield (decode, (qp_d, dcache, dtok), f"{arch}/{rtag}/decode",
               {"quantized": True,
                "expect_no_f32_dot": no_f32 and cfg8 is not None})


def registry_traces(arch: str, *, batch: int = 2, prefill_len: int = 32,
                    decode_len: int = 64,
                    recipes: Sequence[str] = ("m2q-w8a8", "uniform8"),
                    hires: Sequence[int] = VISION_HIRES) -> List[Trace]:
    """The qlint trace set for one registry config — every spec from
    :func:`registry_trace_specs` lowered AND compiled under kernel
    dispatch."""
    return [trace_fn(fn, args, name=name, meta=meta)
            for fn, args, name, meta in registry_trace_specs(
                arch, batch=batch, prefill_len=prefill_len,
                decode_len=decode_len, recipes=recipes, hires=hires)]


def shape_requests(configs: Sequence[str], *,
                   recipes: Sequence[str] = ("m2q-w8a8", "uniform8"),
                   batch: int = 2, prefill_len: int = 32,
                   decode_len: int = 64,
                   hires: Sequence[int] = VISION_HIRES):
    """Enumerate every autotune shape a deployment's hot paths request.

    Lowers (does NOT compile) each :func:`registry_trace_specs` entry under
    kernel dispatch with ``autotune.record_requests`` listening: block
    choices resolve at Python trace time, so lowering alone walks every
    ``blocks_for``/``note_shape`` call site with the real launch shapes.
    Returns ``(requests, per_trace)``: deduplicated ShapeRequests in first-
    seen order, and {trace name: request count} for coverage reporting.
    """
    from ..kernels import autotune
    reqs: List[autotune.ShapeRequest] = []
    per_trace: Dict[str, int] = {}
    for arch in configs:
        for fn, args, name, _meta in registry_trace_specs(
                arch, batch=batch, prefill_len=prefill_len,
                decode_len=decode_len, recipes=recipes, hires=hires):
            n0 = len(reqs)
            with autotune.record_requests(reqs), \
                    ops.dispatch(dense=True, conv=True, attn=True):
                jax.jit(fn).lower(*args)
            per_trace[name] = len(reqs) - n0
    seen, out = set(), []
    for r in reqs:
        if r not in seen:
            seen.add(r)
            out.append(r)
    return out, per_trace


def _norm_spec(spec, ndim: int) -> str:
    """PartitionSpec -> canonical string (trailing Nones stripped)."""
    parts = list(getattr(spec, "_partitions", None) or tuple(spec or ()))
    while parts and parts[-1] is None:
        parts.pop()
    return repr(tuple(parts))


def sharded_decode_trace(arch: str, *, batch: int = 4, decode_len: int = 32,
                         n_data: int = 2, n_model: int = 2,
                         recipe: str = "m2q-w8a8") -> Trace:
    """One mesh-sharded decode trace with sharding-conformance metadata:
    expected specs from ``dist.sharding``, actual from the compiled
    executable's input shardings.  Requires >= n_data*n_model devices
    (the qlint CLI forces virtual host devices before importing jax)."""
    from ..dist import sharding as shd
    from ..launch.mesh import make_debug_mesh

    cfg = _resolve_reduced(arch)
    cfg = _int8_kv_cfg(cfg) or cfg
    model = get_model(cfg)
    mesh = make_debug_mesh(n_data, n_model)
    qp = abstract_quantize(cfg, tokens_per_step=batch, recipe=recipe)
    cache, tokens = decode_inputs(cfg, batch, decode_len)
    in_specs = (shd.param_specs(qp, mesh, fsdp=False),
                shd.cache_specs(cache, mesh, shard_model=True),
                shd.batch_specs(tokens, mesh))
    in_shardings = shd.shardings_from_specs(in_specs, mesh)

    def decode(p, c, t, _cfg=cfg, _model=model):
        return _model.decode_step(_cfg, p, c, t)

    tr = trace_fn(decode, (qp, cache, tokens),
                  name=f"{arch}/m2q/decode-sharded",
                  meta={"quantized": True}, in_shardings=in_shardings)
    # expected spec per pytree path (full flattening) ...
    is_spec = lambda x: x is None or isinstance(x, jax.sharding.PartitionSpec)
    exp_by_path = {
        _path_str(kp): spec
        for (kp, spec) in jax.tree_util.tree_flatten_with_path(
            in_specs, is_leaf=is_spec)[0]}
    # ... vs the executable's input shardings, which (like the HLO entry
    # parameters) cover only the SURVIVING argument leaves — align both
    # through the per-parameter path attribution
    act_leaves = jax.tree.leaves(
        tr.compiled.input_shardings[0],
        is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
    aligned = tr._aligned_paths()
    records: List[Dict[str, str]] = []
    if aligned is not None and len(aligned) == len(act_leaves):
        for path, a in zip(aligned, act_leaves):
            a_spec = getattr(a, "spec", None)
            records.append({
                "path": path,
                "expected": _norm_spec(exp_by_path.get(path), 0),
                "actual": _norm_spec(a_spec, 0) if a_spec is not None
                else repr(a),
            })
    else:  # surface the drift instead of silently skipping the rule
        records.append({"path": "<tree>",
                        "expected": f"{len(exp_by_path)} spec leaves",
                        "actual": f"{len(act_leaves)} sharding leaves"})
    tr.meta["sharding"] = records
    return tr


DEFAULT_SWEEP: Tuple[str, ...] = (
    "efficientvit-b1-r224",
    "qwen1.5-0.5b",
    "granite-3-8b",
    "rwkv6-3b",
    "whisper-large-v3",
    "llama4-scout-17b-a16e",
)
