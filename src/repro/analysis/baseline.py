"""qlint baseline: committed known-violation ledger + diffing.

The sweep's job is to catch REGRESSIONS, not to force every by-design
deviation to zero: the M2Q APoT half contracts its decoded values at f32
on purpose (the SAT engine), and the activation-quantize converts are a
documented detection boundary.  Those land in
``results/qlint_baseline.json`` once, reviewed; the CLI then exits
nonzero only on violations NOT in the baseline (new (trace, rule, path)
keys, or a count increase on an existing key).

The ledger keys on (trace, rule, path) with a count — instruction names
are NOT stable across recompiles, so violations aggregate by their
path/bucket attribution, which is.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Iterable, List

from .rules import Violation

SCHEMA_VERSION = 1

Ledger = Dict[str, Dict[str, Dict[str, int]]]  # trace -> rule -> path -> n


def to_ledger(violations: Iterable[Violation]) -> Ledger:
    led: Ledger = {}
    for v in violations:
        led.setdefault(v.trace, {}).setdefault(v.rule, {})
        led[v.trace][v.rule][v.path] = led[v.trace][v.rule].get(v.path, 0) + 1
    return led


def diff(current: Ledger, baseline: Ledger) -> List[str]:
    """Human-readable regressions: keys/counts in ``current`` beyond
    ``baseline``.  Violations that DISAPPEARED are not regressions (run
    ``--update-baseline`` to ratchet them out)."""
    out = []
    for trace in sorted(current):
        for rule in sorted(current[trace]):
            for path, n in sorted(current[trace][rule].items()):
                base = baseline.get(trace, {}).get(rule, {}).get(path)
                if base is None:
                    out.append(f"NEW  {trace} :: {rule} :: {path or '<module>'}"
                               f" (x{n})")
                elif n > base:
                    out.append(f"GREW {trace} :: {rule} :: {path or '<module>'}"
                               f" ({base} -> {n})")
    return out


def improvements(current: Ledger, baseline: Ledger) -> List[str]:
    """Baseline entries no longer observed — candidates for ratcheting."""
    out = []
    for trace in sorted(baseline):
        for rule in sorted(baseline[trace]):
            for path, n in sorted(baseline[trace][rule].items()):
                cur = current.get(trace, {}).get(rule, {}).get(path, 0)
                if cur < n:
                    out.append(f"GONE {trace} :: {rule} :: "
                               f"{path or '<module>'} ({n} -> {cur})")
    return out


def load(path) -> Ledger:
    data = json.loads(Path(path).read_text())
    if data.get("version") != SCHEMA_VERSION:
        raise ValueError(
            f"qlint baseline {path} has schema version "
            f"{data.get('version')!r}, this tool writes {SCHEMA_VERSION}")
    return data["violations"]


def save(path, ledger: Ledger) -> None:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(
        {"version": SCHEMA_VERSION, "violations": ledger},
        indent=2, sort_keys=True) + "\n")
