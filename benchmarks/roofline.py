"""Roofline analysis from the multi-pod dry-run artifacts (EXPERIMENTS.md
§Roofline).

Per (arch x shape x mesh) cell, three terms in seconds (TPU v5e):
  compute   = HLO dot-FLOPs / peak  (bf16 197 TF/s; int8 dots at 2x = 394)
  memory    = HLO bytes / 819 GB/s  (argument + output + 2*temp per device)
  collective= HLO collective bytes / 50 GB/s per ICI link
All inputs are PER DEVICE (the SPMD HLO module is per-partition; the
loop-aware analyzer in launch.hlo_analysis recovers scan trip counts).

MODEL_FLOPS = 6*N_active*tokens (train) or 2*N_active*tokens (inference) —
the useful-matmul yardstick; ratio = MODEL_FLOPS / (HLO_FLOPs * chips)
catches remat/replication waste.  roofline_fraction = ideal-compute-time /
dominant-term = the score we hillclimb in §Perf.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, Optional

PEAK_BF16 = 197e12
PEAK_INT8 = 394e12   # int8 MXU rate (the M2Q uniform-half advantage)
PEAK_F32 = 49e12     # f32 dots don't hit the MXU's bf16 path
HBM_BW = 819e9
LINK_BW = 50e9

ROOT = pathlib.Path(__file__).resolve().parent.parent


def default_baseline_path():
    v2 = ROOT / "results" / "dryrun_v2.jsonl"
    return v2 if v2.exists() else ROOT / "results" / "dryrun.jsonl"


def load_cells(path=None) -> Dict[tuple, dict]:
    path = path or default_baseline_path()
    cells: Dict[tuple, dict] = {}
    if not pathlib.Path(path).exists():
        return cells
    for line in open(path):
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        key = (r.get("arch"), r.get("shape"), r.get("mesh"))
        # last record wins (re-runs after fixes supersede failures)
        if r.get("status") == "ok" or key not in cells:
            cells[key] = r
    return cells


_CACHE_BYTES: Dict[tuple, int] = {}


def _cache_bytes(arch: str, shape_name: str) -> int:
    """Global KV/state cache bytes for a serve cell (eval_shape, no alloc)."""
    key = (arch, shape_name)
    if key not in _CACHE_BYTES:
        import numpy as np
        from repro.configs.registry import ARCHS
        from repro.launch.specs import SHAPES, decode_inputs
        cfg = ARCHS[arch]
        sh = SHAPES[shape_name]
        cache, _ = decode_inputs(cfg, sh.batch, sh.seq)
        import jax
        _CACHE_BYTES[key] = int(sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree.leaves(cache)))
    return _CACHE_BYTES[key]


def _min_bytes(rec: dict) -> float:
    """Workload-inherent HBM traffic floor (global bytes/step)."""
    if rec["kind"] == "train":
        # f32 params+adam m/v: read p,m,v + write p,m,v = 24 B/param, plus
        # one activation write+read per token per layer floor (bf16)
        return 24.0 * rec.get("n_params", 0)
    base = rec.get("serving_weight_bytes", 8 * rec.get("n_params", 0) // 8)
    if rec["kind"] in ("decode", "prefill"):
        base += rec.get("cache_bytes") or _cache_bytes(rec["arch"],
                                                       rec["shape"])
    return float(base)


def terms_for(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    hlo = rec.get("hlo", {})
    by_dt = hlo.get("dot_flops_by_dtype", {})
    f_int = sum(v for k, v in by_dt.items() if k in ("s8", "u8", "s4", "u4"))
    f_f32 = sum(v for k, v in by_dt.items() if k in ("f32", "f64"))
    f_bf16 = hlo.get("dot_flops", 0.0) - f_int - f_f32
    t_compute = f_bf16 / PEAK_BF16 + f_f32 / PEAK_F32 + f_int / PEAK_INT8
    ma = rec.get("memory_analysis", {})
    bytes_dev = (ma.get("argument_size_in_bytes", 0)
                 + ma.get("output_size_in_bytes", 0)
                 + 2 * ma.get("temp_size_in_bytes", 0))
    t_memory = bytes_dev / HBM_BW
    coll = hlo.get("collective_total_bytes", 0.0)
    t_coll = coll / LINK_BW
    chips = 512 if rec["mesh"] == "multi" else 256
    tokens = rec["batch"] * (rec["seq"] if rec["kind"] in ("train", "prefill")
                             else 1)
    n_act = rec.get("n_active_params", 0)
    model_flops = (6 if rec["kind"] == "train" else 2) * n_act * tokens
    hlo_total = hlo.get("dot_flops", 0.0) * chips
    dominant = max((t_compute, "compute"), (t_memory, "memory"),
                   (t_coll, "collective"))
    # workload-inherent ideal: perfectly sharded compute AND the minimal HBM
    # traffic (weights+cache for serving; params+optimizer for training)
    ideal_c = model_flops / (chips * PEAK_BF16)
    ideal_m = _min_bytes(rec) / (chips * HBM_BW)
    ideal = max(ideal_c, ideal_m)
    return {
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant[1], "dominant_s": dominant[0],
        "model_flops": model_flops,
        "ideal_s": ideal, "ideal_bound": "compute" if ideal_c >= ideal_m
        else "memory",
        "useful_ratio": model_flops / hlo_total if hlo_total else 0.0,
        "roofline_fraction": ideal / dominant[0] if dominant[0] else 0.0,
        "bytes_per_device": bytes_dev,
        "hbm_fit": bytes_dev - ma.get("temp_size_in_bytes", 0) <= 16e9,
    }


_SUGGEST = {
    "memory": "cut bytes: shard KV/cache over model axis, lower-bit weights,"
              " smaller remat footprint",
    "compute": "cut replicated FLOPs: shard attention heads/d_head, move more"
               " dots to int8 (2x MXU rate)",
    "collective": "reduce resharding: align layer in/out shardings, compress"
                  " gradients, overlap collectives with compute",
}


def build_table(path=None):
    cells = load_cells(path)
    rows = []
    for (arch, shape, mesh), rec in sorted(cells.items()):
        if rec.get("status") == "skipped":
            rows.append({"arch": arch, "shape": shape, "mesh": mesh,
                         "status": "skipped", "reason": rec.get("reason", "")})
            continue
        t = terms_for(rec)
        if t is None:
            rows.append({"arch": arch, "shape": shape, "mesh": mesh,
                         "status": rec.get("status", "?")})
            continue
        rows.append({"arch": arch, "shape": shape, "mesh": mesh,
                     "status": "ok", **t,
                     "suggest": _SUGGEST[t["dominant"]]})
    return rows


def write_reports(path=None, out_csv=None, out_md=None):
    """Writes the baseline roofline table; if the optimized sweep exists,
    each row also carries the optimized fraction + speedup."""
    rows = build_table(path)
    opt_path = ROOT / "results" / "dryrun_opt.jsonl"
    if opt_path.exists():
        opt = {(r["arch"], r["shape"], r["mesh"]): r
               for r in build_table(opt_path) if r.get("status") == "ok"}
        for r in rows:
            o = opt.get((r["arch"], r["shape"], r["mesh"]))
            if o and r.get("status") == "ok":
                r["opt_fraction"] = o["roofline_fraction"]
                r["opt_dominant"] = o["dominant"]
                r["speedup"] = (o["roofline_fraction"]
                                / max(r["roofline_fraction"], 1e-12))
    out_csv = out_csv or ROOT / "results" / "roofline.csv"
    out_md = out_md or ROOT / "results" / "roofline.md"
    cols = ["arch", "shape", "mesh", "status", "compute_s", "memory_s",
            "collective_s", "dominant", "useful_ratio", "roofline_fraction",
            "opt_fraction", "opt_dominant", "speedup"]
    with open(out_csv, "w") as f:
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(
                f"{r.get(c):.4g}" if isinstance(r.get(c), float)
                else str(r.get(c, "")) for c in cols) + "\n")
    md = ["| " + " | ".join(cols) + " |",
          "|" + "|".join(["---"] * len(cols)) + "|"]
    for r in rows:
        md.append("| " + " | ".join(
            f"{r.get(c):.3g}" if isinstance(r.get(c), float)
            else str(r.get(c, "")) for c in cols) + " |")
    pathlib.Path(out_md).write_text("\n".join(md) + "\n")
    return rows
