"""Microbenchmarks for the seven Pallas kernels + the M2Q dispatch chain.

Emits ``BENCH_kernels.json``: per-kernel wall-clock and loop-aware HLO op
counts (via repro.launch.hlo_analysis.op_histogram), plus a fused-vs-legacy
comparison of the M2Q layer epilogue — the fused permutation-free path must
show ZERO standalone gather/concatenate ops, the legacy concat+``take``
epilogue it replaced shows both.  The ``attn`` section contrasts the fused
int8 attention kernels against the XLA-int8 and f32 paths for MSA shapes
(B1/B2 at R224) and int8-KV decode shapes (serving batch sizes); its
``msa*`` fused/f32 pairs feed ``accel_sim.KernelCalibration`` the same way
the conv rows do.  Wall-clocks on the CPU interpret path are not kernel
latencies (the container has no TPU) but they pin the dispatch overhead
trend from PR to PR; on a TPU backend the same harness times the real
kernels with autotuned blocks.

  PYTHONPATH=src python -m benchmarks.kernel_bench [out.json]
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_OUT = Path(__file__).resolve().parent / "BENCH_kernels.json"
_TRACKED_OPS = ("gather", "concatenate", "convolution", "dot", "fusion",
                "custom-call", "scatter", "pad", "slice", "while")


def _hist_summary(hist):
    out = {op: int(hist.get(op, 0)) for op in _TRACKED_OPS}
    out["total"] = int(sum(hist.values()))
    return out


def _bench_one(name, fn, args, iters=3):
    from repro.kernels.autotune import measure
    from repro.launch.hlo_analysis import op_histogram
    txt = jax.jit(fn).lower(*args).compile().as_text()
    return {
        "wall_s": round(measure(fn, *args, reps=iters), 6),
        "ops": _hist_summary(op_histogram(txt)),
        # strictest view: counts fusion interiors too — the legacy
        # concat+take epilogue surfaces here even after XLA fuses it
        "ops_incl_fused": _hist_summary(
            op_histogram(txt, include_fused=True)),
    }


def collect_attn(iters: int = 3, smoke: bool = False) -> dict:
    """Attention rows: fused Pallas vs XLA-int8 vs f32.

    ``msa_*`` rows are EfficientViT ReLU-linear-attention shapes (B1/B2
    stage-3 token counts at R224; heads = C / dim_per_head); ``decode_*``
    rows are int8-KV decode-attention shapes at serving batch sizes.  The
    fused and xla_int8 variants compute the SAME int8 math (kernel vs
    einsum); f32 is the unquantized baseline the accel-sim calibration
    derates against.  ``smoke=True`` shrinks every shape for the test
    suite's fast interpret-mode pass.
    """
    from repro import nn
    from repro.kernels import ops, ref
    from repro.core.quant import act_scale_from_stats

    rng = np.random.default_rng(7)
    rows = {}

    msa_shapes = ([("msa_smoke", 1, 16, 2, 8)] if smoke else
                  [("msa_b1_r224", 1, 196, 8, 16),
                   ("msa_b2_r224", 1, 196, 6, 32)])
    for name, B, N, H, D in msa_shapes:
        q, k, v = (jnp.asarray(rng.normal(0, 1, (B, N, H, D))
                               .astype(np.float32)) for _ in range(3))
        with ops.dispatch(attn=True):
            rows[f"{name}/fused"] = _bench_one(
                name, lambda a, b, c: nn.relu_linear_attention(a, b, c),
                (q, k, v), iters)
        sq = act_scale_from_stats(jnp.maximum(jnp.max(q), 0.0))
        sk = act_scale_from_stats(jnp.maximum(jnp.max(k), 0.0))
        sv = act_scale_from_stats(jnp.max(jnp.abs(v)))
        rows[f"{name}/xla_int8"] = _bench_one(
            name, lambda a, b, c: ref.relu_attn_ref(a, b, c, sq, sk, sv),
            (q, k, v), iters)
        with ops.dispatch(attn=False):
            rows[f"{name}/f32"] = _bench_one(
                name, lambda a, b, c: nn.relu_linear_attention(a, b, c),
                (q, k, v), iters)

    decode_shapes = ([("decode_smoke", 2, 16, 4, 2, 8)] if smoke else
                     [("decode_b4", 4, 256, 8, 4, 64),
                      ("decode_b8", 8, 256, 8, 8, 64)])
    for name, B, T, Hq, Hkv, D in decode_shapes:
        q = jnp.asarray(rng.normal(0, 1, (B, 1, Hq, D)).astype(np.float32))
        kc = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, D)).astype(np.float32))
        vc = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, D)).astype(np.float32))
        k8, ks = nn.quantize_kv_rows(kc)
        v8, vs = nn.quantize_kv_rows(vc)
        lengths = jnp.asarray(
            rng.integers(T // 2, T + 1, (B,)).astype(np.int32))
        with ops.dispatch(attn=True):
            rows[f"{name}/fused"] = _bench_one(
                name, lambda *a: nn.decode_attention_int8(*a),
                (q, k8, v8, ks, vs, lengths), iters)
        with ops.dispatch(attn=False):
            rows[f"{name}/xla_int8"] = _bench_one(
                name, lambda *a: nn.decode_attention_int8(*a),
                (q, k8, v8, ks, vs, lengths), iters)
        rows[f"{name}/f32"] = _bench_one(
            name, lambda *a: nn.decode_attention(*a),
            (q, kc, vc, lengths), iters)
    return rows


def collect(shape=(128, 128, 128), iters: int = 3) -> dict:
    from repro.core import QAPoT, QM2Q, QUniform, select_schemes
    from repro.core.packing import pack_int4
    from repro.core.quant import uniform_quantize
    from repro.kernels import ops

    M, K, N = shape
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (M, K)).astype(np.float32))
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    sa = jnp.float32(float(np.abs(np.asarray(x)).max()) / 127.0)
    interpret = jax.default_backend() != "tpu"

    report = {"backend": jax.default_backend(), "interpret": interpret,
              "shape": list(shape), "unix_time": int(time.time()),
              "kernels": {}, "m2q_paths": {}}

    q8 = QUniform.quantize(jnp.asarray(w), bits=8)
    report["kernels"]["int8_matmul"] = _bench_one(
        "int8_matmul",
        lambda xx: ops.int8_matmul_op(xx, q8.payload, sa,
                                      q8.scale.reshape(-1),
                                      q8.zero_point.reshape(-1),
                                      interpret=interpret),
        (x,), iters)

    q4 = QUniform.quantize(jnp.asarray(w), bits=4)
    report["kernels"]["int4_matmul"] = _bench_one(
        "int4_matmul",
        lambda xx: ops.int4_matmul_op(xx, q4.payload, q4.scale.reshape(-1),
                                      q4.zero_point.reshape(-1),
                                      interpret=interpret),
        (x,), iters)

    qa = QAPoT.quantize(jnp.asarray(w))
    report["kernels"]["apot_matmul"] = _bench_one(
        "apot_matmul",
        lambda xx: ops.apot_matmul_op(xx, qa.codes, qa.scale.reshape(-1),
                                      interpret=interpret),
        (x,), iters)

    asn = select_schemes(jnp.asarray(w), ratio=0.5)
    qm = QM2Q.quantize(jnp.asarray(w), asn.apot_idx, asn.uniform_idx,
                       act_max_abs=jnp.float32(3.0))
    report["kernels"]["m2q_matmul"] = _bench_one(
        "m2q_matmul",
        lambda xx: ops.m2q_matmul_op(xx, qm.act_scale, qm.payload,
                                     qm.u_scale.reshape(-1),
                                     qm.u_zp.reshape(-1),
                                     qm.a_scale.reshape(-1),
                                     interpret=interpret),
        (x,), iters)

    C = max(32, (N // 4) * 2)
    wc = rng.normal(0, 0.2, (3, 3, C)).astype(np.float32)
    uc = uniform_quantize(jnp.asarray(wc), bits=4, axis=-1)
    packed = pack_int4(uc.q.reshape(9, C))
    xc = jnp.asarray(rng.normal(0, 1, (2, 16, 16, C)).astype(np.float32))
    report["kernels"]["dwconv_w4"] = _bench_one(
        "dwconv_w4",
        lambda xx: ops.dwconv_w4_op(xx, packed, uc.scale.reshape(-1),
                                    uc.zero_point.reshape(-1),
                                    interpret=interpret),
        (xc,), iters)

    # --- M2Q layer epilogue: fused permutation-free vs legacy concat+take --
    report["m2q_paths"]["fused"] = _bench_one(
        "m2q_fused", lambda xx: qm.matmul(xx), (x,), iters)

    ui = jnp.asarray(asn.uniform_idx, jnp.int32)
    ai = jnp.asarray(asn.apot_idx, jnp.int32)
    inv_perm = jnp.argsort(jnp.concatenate([ui, ai])).astype(jnp.int32)
    qu_half = QUniform.quantize(jnp.asarray(w)[:, ui], bits=8,
                                act_max_abs=jnp.float32(3.0))
    qa_half = QAPoT.quantize(jnp.asarray(w)[:, ai],
                             act_max_abs=jnp.float32(3.0))

    def legacy(xx):  # the epilogue this PR deleted
        y = jnp.concatenate([qu_half.matmul(xx), qa_half.matmul(xx)], axis=-1)
        return jnp.take(y, inv_perm, axis=-1)

    report["m2q_paths"]["legacy_concat_take"] = _bench_one(
        "m2q_legacy", legacy, (x,), iters)

    # --- quantized conv dispatch: fused / XLA-QTensor / f32-fallback -------
    # PWConv (B1/B2 late-stage widths) + depthwise (3x3 MBConv, 5x5 MSA agg)
    # at a 7x7 late-stage map.  Each variant is the SAME nn.conv2d call
    # under a scoped kernels.ops.DispatchConfig — programmatic, per-row
    # dispatch control instead of flipping process-global env vars.  The
    # fused and XLA-QTensor paths must emit ZERO convolution ops (PWConv is
    # a matmul; dwconv runs the packed-w4 kernel); the dequantized-f32
    # fallback they replaced shows the conv.
    import dataclasses
    from repro import nn

    report["conv"] = {}
    for name, cin, cout in (("pwconv_b1", 256, 256), ("pwconv_b2", 384, 384)):
        wc4 = rng.normal(0, 0.05, (1, 1, cin, cout)).astype(np.float32)
        w2 = jnp.asarray(wc4.reshape(cin, cout))
        asn_c = select_schemes(w2, ratio=0.5)
        qc = QM2Q.quantize(w2, asn_c.apot_idx, asn_c.uniform_idx,
                           act_max_abs=jnp.float32(3.0))
        qc = dataclasses.replace(qc, shape=wc4.shape)
        xc4 = jnp.asarray(rng.normal(0, 1, (1, 7, 7, cin)).astype(np.float32))
        with ops.dispatch(dense=True, conv=True):
            report["conv"][f"{name}/fused"] = _bench_one(
                name, lambda xx, q=qc: nn.conv2d(xx, q), (xc4,), iters)
        with ops.dispatch(dense=False, conv=False):
            report["conv"][f"{name}/xla_qtensor"] = _bench_one(
                name, lambda xx, q=qc: nn.conv2d(xx, q), (xc4,), iters)
        report["conv"][f"{name}/f32_dequant_conv"] = _bench_one(
            name, lambda xx, q=qc: jax.lax.conv_general_dilated(
                xx, q.dequant(jnp.float32).reshape(q.shape), (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC")), (xc4,), iters)

    # late-stage widths at a 7x7 map, plus high-resolution maps (R256
    # stride-1, R384 stride-2 with in-kernel SAME padding) that the old
    # whole-map VMEM guard used to bounce to the XLA fallback — these rows
    # pin the H-tiled kernel's wall-clock and feed the accel-sim dw
    # calibration across the resolution range
    for name, k, ch, hw, s in (("dwconv3x3_b1", 3, 256, 7, 1),
                               ("dwconv5x5_b2", 5, 1152, 7, 1),
                               ("dwconv3x3_r256", 3, 32, 256, 1),
                               ("dwconv3x3_r384", 3, 32, 384, 2)):
        wdw = rng.normal(0, 0.2, (k * k, ch)).astype(np.float32)
        udw = uniform_quantize(jnp.asarray(wdw), bits=4, axis=-1)
        qdw = QUniform(payload=pack_int4(udw.q), scale=udw.scale,
                       zero_point=udw.zero_point, act_scale=None, bits=4,
                       axis=1, shape=(k, k, 1, ch))
        xdw = jnp.asarray(
            rng.normal(0, 1, (1, hw, hw, ch)).astype(np.float32))
        with ops.dispatch(conv=True):
            report["conv"][f"{name}/fused"] = _bench_one(
                name, lambda xx, q=qdw, st=s: nn.dwconv2d(xx, q, stride=st),
                (xdw,), iters)
        report["conv"][f"{name}/f32_dequant_conv"] = _bench_one(
            name, lambda xx, q=qdw, st=s: jax.lax.conv_general_dilated(
                xx, q.dequant(jnp.float32).reshape(q.shape), (st, st),
                "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"),
                feature_group_count=ch), (xdw,), iters)

    # --- attention: fused Pallas vs XLA-int8 vs f32 ------------------------
    report["attn"] = collect_attn(iters=iters)
    return report


def write_report(out_path=DEFAULT_OUT, shape=(128, 128, 128),
                 iters: int = 3) -> dict:
    report = collect(shape=shape, iters=iters)
    fused = report["m2q_paths"]["fused"]["ops_incl_fused"]
    assert fused["gather"] == 0 and fused["concatenate"] == 0, fused
    for name, rec in report["conv"].items():
        convs = rec["ops_incl_fused"]["convolution"]
        if name.endswith("/f32_dequant_conv"):
            # the depthwise f32 baseline keeps its convolution op (guards a
            # vacuous check); XLA canonicalizes the 1x1 f32 conv to a dot,
            # so only the dwconv baselines discriminate here
            assert name.startswith("pwconv") or convs >= 1, (name, rec)
        else:  # fused + XLA-QTensor quantized paths: no convolution op
            assert convs == 0, (name, rec)
    # every attention base ships the full fused/xla_int8/f32 contrast (the
    # accel-sim calibration divides fused by f32 per msa base)
    attn_bases = {n.partition("/")[0] for n in report["attn"]}
    for base in attn_bases:
        for variant in ("fused", "xla_int8", "f32"):
            assert report["attn"][f"{base}/{variant}"]["wall_s"] > 0, base
    Path(out_path).write_text(json.dumps(report, indent=1, sort_keys=True))
    return report


def print_report(report) -> None:
    """CSV-ish summary lines (shared by this CLI and benchmarks.run)."""
    for section in ("kernels", "m2q_paths", "conv", "attn"):
        prefix = {"kernels": "kernel", "m2q_paths": "m2q_path",
                  "conv": "conv", "attn": "attn"}[section]
        for name, rec in report.get(section, {}).items():
            o = rec["ops_incl_fused"]
            print(f"{prefix}/{name},{rec['wall_s']},"
                  f"gather={o['gather']} concat={o['concatenate']} "
                  f"conv={o['convolution']}")


def main():
    out = Path(sys.argv[1]) if len(sys.argv) > 1 else DEFAULT_OUT
    report = write_report(out)
    print_report(report)
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
