"""Cycle/energy model of the M2-ViT accelerator (paper Sec. IV-V).

The paper evaluates with a cycle-level simulator fed by Synopsys-synthesized
unit energies (28nm TSMC, 500 MHz; Table VI).  This module reproduces that
methodology:

* engine geometry from Sec. V-A: (R x M x T + N x S) x L
  = (3 x 3 x 16 + 9 x 8) x 16 — MPMA: 144 4x8-bit multipliers/core
  (single mode) == 72 8x8 merged pairs; SAT: 72 shifter units/core.
* unit energies from Table VI (power @ 500MHz -> J/op = P/f):
    8x8 mult (Trio-ViT)              2.63e-2 mW -> 52.6 fJ/MAC
    precision-scalable mult (ours)   2.54e-2 mW -> 50.8 fJ/MAC (8x8 mode)
                                                -> 25.4 fJ/MAC (4x8 mode)
    shifter unit (APoT MAC)          1.06e-2 mW -> 21.2 fJ/MAC
* weight-buffer read energy per bit: ONE calibration constant fitted so the
  Trio-ViT baseline reproduces Table III's 26.06 uJ at B1-R224; everything
  else (other resolutions, B2, the mixed schemes, EDP) is then *predicted*
  and compared against the paper (bench_table3/5).
* execution flow (Sec. IV): per block, the APoT filter half runs on SAT
  concurrently with the uniform half on MPMA -> block latency is the max of
  the two engine times; DWConvs run on MPMA overlapped with the previous
  block's SAT work.
"""
from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import List, Optional

# ---------------------------------------------------------------------------
# hardware constants (paper Table VI + Sec. V-A)
# ---------------------------------------------------------------------------

FREQ_HZ = 500e6
L_CORES = 16
MPMA_MULTS = 3 * 3 * 16          # 4x8 multipliers per core (single mode)
MPMA_PAIRS = MPMA_MULTS // 2     # 8x8 merged pairs per core
SAT_UNITS = 9 * 8                # shifter units per core

E_MAC88_TRIO = 2.63e-2 * 1e-3 / FREQ_HZ   # J per 8x8 MAC (Trio-ViT unit)
E_MAC88_OURS = 2.54e-2 * 1e-3 / FREQ_HZ   # precision-scalable, 8x8 mode
E_MAC48_OURS = E_MAC88_OURS / 2.0         # two 4x8 ops per pair
E_APOT_MAC = 1.06e-2 * 1e-3 / FREQ_HZ     # shifter unit (2 shifts + add)
E_POT_MAC = E_APOT_MAC / 2.0              # single-shift PoT (Auto-ViT-Acc)

# fitted on Trio-ViT B1-R224 = 26.06 uJ (Table III); see fit_buffer_energy()
E_WBUF_PER_BIT = 1.05e-13  # J/bit, overwritten by fit at import of run.py
E_ABUF_PER_BIT = 0.0       # folded into E_WBUF fit (act reuse is high)


@dataclasses.dataclass
class Layer:
    name: str
    kind: str          # 'dw' | 'pw' | 'matmul' | 'head'
    macs: int          # multiply-accumulates
    n_weights: int
    out_elems: int     # output activations (weight-reuse denominator)


@dataclasses.dataclass
class LayerEnergy:
    name: str
    compute_j: float
    wbuf_j: float
    mpma_cycles: float
    sat_cycles: float


@dataclasses.dataclass
class SimResult:
    energy_uj: float          # computational energy (Table III scope)
    latency_ms: float
    throughput_gops: float
    edp_mj_ms: float
    energy_mj_total: float    # Table V scope (adds buffer+static overhead)
    per_layer: List[LayerEnergy]
    # request-level latency behind the serving runtime (set only when
    # simulate() was given a ServingCalibration): device latency derated
    # by measured batch occupancy + measured queue wait
    served_latency_ms: Optional[float] = None
    served_p99_latency_ms: Optional[float] = None


# ---------------------------------------------------------------------------
# quantization methods
# ---------------------------------------------------------------------------


def _layer_cost(layer: Layer, method: str):
    """Returns (compute_J, weight_bits_fetched, mpma_cycles, sat_cycles)."""
    m = layer.macs
    nw = layer.n_weights
    # weight fetches: weights stream once per output-tile pass; the paper's
    # dataflows reuse weights across T output pixels -> fetch count ~=
    # macs / reuse, reuse = T (=16) output pixels, floor at n_weights.
    fetches = max(nw, m // 16)

    if method == "fp32":
        return m * 4 * E_MAC88_TRIO, fetches * 32, None, None
    if method == "trio":  # uniform W8A8, everything on 8x8 multipliers
        cyc = m / (MPMA_PAIRS * L_CORES)
        return m * E_MAC88_TRIO, fetches * 8, cyc, 0.0
    if method == "m2q":
        if layer.kind == "dw":  # 4-bit single mode
            cyc = m / (MPMA_MULTS * L_CORES)
            return m * E_MAC48_OURS, fetches * 4, cyc, 0.0
        # mixed: half filters uniform-8 on MPMA, half APoT on SAT (parallel)
        e = 0.5 * m * E_MAC88_OURS + 0.5 * m * E_APOT_MAC
        bits = 0.5 * fetches * 8 + 0.5 * fetches * 7
        return e, bits, 0.5 * m / (MPMA_PAIRS * L_CORES), \
            0.5 * m / (SAT_UNITS * L_CORES)
    if method == "autovit":  # PoT/uniform mixed scheme, W8 everywhere
        e = 0.5 * m * E_MAC88_OURS + 0.5 * m * E_POT_MAC
        bits = 0.5 * fetches * 8 + 0.5 * fetches * 4  # 4-bit PoT codes
        return e, bits, 0.5 * m / (MPMA_PAIRS * L_CORES), \
            0.5 * m / (SAT_UNITS * L_CORES)
    raise ValueError(method)


# ---------------------------------------------------------------------------
# measured-kernel latency calibration (BENCH_kernels.json conv rows)
# ---------------------------------------------------------------------------

DEFAULT_KERNEL_BENCH = Path(__file__).resolve().parent / "BENCH_kernels.json"

# which measured contrast calibrates which simulator layer kind: PWConvs and
# the head run the fused (m2q/int8) matmul kernels, DWConvs the packed-w4
# conv kernel, and the attention MatMuls the fused relu_attn kernel (msa
# rows of the bench's attn section)
_KIND_TO_BENCH = {"pw": "pw", "matmul": "attn", "head": "pw", "dw": "dw"}


@dataclasses.dataclass(frozen=True)
class KernelCalibration:
    """Measured fused-vs-f32-fallback kernel speedups from kernel_bench.

    The cycle model above assumes the quantized engines hit their ideal
    mapping (e.g. a mixed PWConv finishes in half the uniform-baseline
    cycles because MPMA and SAT run the two halves in parallel).  The
    kernel microbenchmark records what the *implemented* hot path actually
    achieves over the f32 fallback; feeding that contrast back derates any
    layer whose measured speedup falls short of the ideal one (never
    crediting beyond the hardware model), so the simulator's latency — and
    therefore its EDP rows — is calibrated against measured kernel
    wall-clock instead of assuming perfection.  Conv rows calibrate the
    pw/dw/head kinds; the attn section's MSA rows calibrate the attention
    MatMul kind (decode rows are LM-serving shapes the vision inventory
    never maps to, so they are reported but not consumed here).
    """

    pw_speedup: float    # geomean fused-vs-f32 wall-clock ratio, PWConv rows
    dw_speedup: float    # same, DWConv rows (7x7 late-stage AND the
    #                      R256/R384 H-tiled high-resolution rows)
    attn_speedup: float  # same, MSA relu-attention rows (attn section)
    backend: str = ""
    source: str = ""
    n_pw: int = 0        # rows behind each geomean — a calibration from a
    n_dw: int = 0        # single pair is legal but worth seeing in reports
    n_attn: int = 0

    @classmethod
    def from_bench_json(cls, path=None) -> "KernelCalibration":
        path = Path(DEFAULT_KERNEL_BENCH if path is None else path)
        data = json.loads(path.read_text())
        conv = data.get("conv") or {}
        attn = data.get("attn") or {}

        def geomean_ratio(rows, prefix: str, baseline: str):
            logs = []
            for name, row in rows.items():
                base, _, variant = name.partition("/")
                if not (base.startswith(prefix) and variant == "fused"):
                    continue
                ref = rows.get(f"{base}/{baseline}")
                if ref and row.get("wall_s") and ref.get("wall_s"):
                    logs.append(math.log(ref["wall_s"] / row["wall_s"]))
            if not logs:
                raise ValueError(
                    f"{path} has no '{prefix}*' fused/{baseline} "
                    "wall-clock pairs (re-run benchmarks.kernel_bench)")
            return math.exp(sum(logs) / len(logs)), len(logs)

        pw, n_pw = geomean_ratio(conv, "pwconv", "f32_dequant_conv")
        dw, n_dw = geomean_ratio(conv, "dwconv", "f32_dequant_conv")
        at, n_at = geomean_ratio(attn, "msa", "f32")
        return cls(pw_speedup=pw, dw_speedup=dw, attn_speedup=at,
                   backend=str(data.get("backend", "")), source=str(path),
                   n_pw=n_pw, n_dw=n_dw, n_attn=n_at)

    def derate(self, kind: str, ideal_speedup: float) -> float:
        """Cycle multiplier for one layer: >1 when the measured kernel
        speedup is below the cycle model's ideal, 1 otherwise (the model
        never runs faster than its hardware mapping allows)."""
        measured = {"pw": self.pw_speedup, "dw": self.dw_speedup,
                    "attn": self.attn_speedup}[
                        _KIND_TO_BENCH.get(kind, "pw")]
        return max(1.0, ideal_speedup / measured)


# ---------------------------------------------------------------------------
# measured serving-occupancy calibration (BENCH_serving.json)
# ---------------------------------------------------------------------------

DEFAULT_SERVING_BENCH = Path(__file__).resolve().parent / "BENCH_serving.json"


@dataclasses.dataclass(frozen=True)
class ServingCalibration:
    """Measured serving-runtime occupancy + queue wait from serving_bench.

    The cycle model prices ONE inference at full engine utilization.  A
    deployed accelerator runs behind the serving runtime, whose measured
    batch occupancy (padded slots still burn cycles) and admission-queue
    wait are what a request actually experiences.  Feeding the committed
    ``BENCH_serving.json`` back in turns the simulator's per-inference
    latency into a SERVED latency:

        served = latency / occupancy + queue_wait

    Occupancy comes from the highest-arrival-rate row (steady state —
    low-rate rows measure deadline flushing, not capacity); queue
    percentiles from the same row.
    """

    occupancy: float      # steady-state batch occupancy in (0, 1]
    queue_p50_ms: float   # measured queue wait at that rate
    queue_p99_ms: float
    backend: str = ""
    source: str = ""

    def __post_init__(self):
        if not 0.0 < self.occupancy <= 1.0:
            raise ValueError(
                f"occupancy must be in (0, 1], got {self.occupancy}")

    @classmethod
    def from_bench_json(cls, path=None,
                        engine: str = "vision") -> "ServingCalibration":
        path = Path(DEFAULT_SERVING_BENCH if path is None else path)
        data = json.loads(path.read_text())
        rows = [r for r in data.get(engine) or []
                if r.get("batch_occupancy")]
        if not rows:
            raise ValueError(
                f"{path} has no '{engine}' rows with batch_occupancy "
                "(re-run benchmarks.serving_bench)")
        row = max(rows, key=lambda r: r.get("arrival_rate_per_s", 0.0))
        return cls(occupancy=float(row["batch_occupancy"]),
                   queue_p50_ms=float(row.get("p50_ms", 0.0)),
                   queue_p99_ms=float(row.get("p99_ms", 0.0)),
                   backend=str(data.get("backend", "")), source=str(path))

    def served_ms(self, latency_ms: float) -> float:
        return latency_ms / self.occupancy + self.queue_p50_ms


def simulate(layers: List[Layer], method: str = "m2q",
             wbuf_per_bit: Optional[float] = None,
             method_for=None,
             kernel_cal: Optional[KernelCalibration] = None,
             serving_cal: Optional[ServingCalibration] = None) -> SimResult:
    """method_for: optional per-layer override (Table IV ablations).
    kernel_cal: optional measured-kernel latency calibration — quantized
    layers whose measured fused-kernel speedup trails the ideal engine
    mapping take proportionally more cycles (energy is unchanged; latency,
    throughput, and EDP move).
    serving_cal: optional measured serving-runtime calibration — fills
    ``SimResult.served_latency_ms`` with what a request sees behind the
    serving loop (device latency derated by measured batch occupancy,
    plus the measured admission-queue wait); the raw device columns are
    untouched."""
    eb = E_WBUF_PER_BIT if wbuf_per_bit is None else wbuf_per_bit
    per_layer = []
    total_macs = 0
    cycles = 0.0
    for layer in layers:
        m_l = method_for(layer) if method_for is not None else method
        e, bits, c_mpma, c_sat = _layer_cost(layer, m_l)
        wj = bits * eb
        total_macs += layer.macs
        if c_mpma is None:  # fp32 reference: no engine mapping
            per_layer.append(LayerEnergy(layer.name, e, wj, 0.0, 0.0))
            cycles += layer.macs / (MPMA_PAIRS * L_CORES)
            continue
        # Sec. IV execution flow: SAT and MPMA halves run in parallel
        c_l = max(c_mpma, c_sat)
        if kernel_cal is not None and m_l in ("m2q", "autovit") and c_l > 0:
            ideal = (layer.macs / (MPMA_PAIRS * L_CORES)) / c_l
            scale = kernel_cal.derate(layer.kind, ideal)
            c_mpma, c_sat, c_l = c_mpma * scale, c_sat * scale, c_l * scale
        per_layer.append(LayerEnergy(layer.name, e, wj, c_mpma, c_sat))
        cycles += c_l
    energy_j = sum(p.compute_j + p.wbuf_j for p in per_layer)
    latency_s = cycles / FREQ_HZ
    ops = 2 * total_macs
    # Table V total energy: computational + buffer/global/static overhead.
    # The overhead power is the Table VI buffer-bank powers + control,
    # modeled as a constant accelerator power draw during the run:
    static_w = 15.0 if method in ("trio", "fp32") else 4.4
    # (ours fitted to Table V's 1.83 mJ; the Trio-ViT *row* of bench_table5
    # uses the paper-reported numbers — Trio's own accelerator geometry is
    # theirs, not ours, so we don't re-simulate it at the Table V scope)
    energy_total_j = energy_j + static_w * latency_s
    latency_ms = latency_s * 1e3
    served = served_p99 = None
    if serving_cal is not None:
        served = serving_cal.served_ms(latency_ms)
        served_p99 = (latency_ms / serving_cal.occupancy
                      + serving_cal.queue_p99_ms)
    return SimResult(
        energy_uj=energy_j * 1e6,
        latency_ms=latency_ms,
        throughput_gops=ops / latency_s / 1e9,
        edp_mj_ms=(energy_total_j * 1e3) * latency_ms,
        energy_mj_total=energy_total_j * 1e3,
        per_layer=per_layer,
        served_latency_ms=served,
        served_p99_latency_ms=served_p99,
    )


# ---------------------------------------------------------------------------
# EfficientViT layer inventories (from the model definition)
# ---------------------------------------------------------------------------


def efficientvit_layers(widths, depths, res: int, dim_per_head: int = 16,
                        n_classes: int = 1000) -> List[Layer]:
    layers: List[Layer] = []
    h = res // 2  # stem stride 2
    cin = widths[0]
    layers.append(Layer("stem", "pw", macs=h * h * 3 * 9 * widths[0],
                        n_weights=27 * widths[0], out_elems=h * h * widths[0]))
    for si, (wd, dp) in enumerate(zip(widths, depths)):
        for bi in range(dp):
            stride = 2 if (bi == 0 and si > 0) else 1
            h_out = h // stride
            mid = cin * 4
            # MBConv = pw expand + dw 3x3 + pw project
            layers.append(Layer(f"s{si}b{bi}.pw1", "pw",
                                macs=h * h * cin * mid,
                                n_weights=cin * mid,
                                out_elems=h * h * mid))
            layers.append(Layer(f"s{si}b{bi}.dw", "dw",
                                macs=h_out * h_out * mid * 9,
                                n_weights=9 * mid,
                                out_elems=h_out * h_out * mid))
            layers.append(Layer(f"s{si}b{bi}.pw2", "pw",
                                macs=h_out * h_out * mid * wd,
                                n_weights=mid * wd,
                                out_elems=h_out * h_out * wd))
            h = h_out
            cin = wd
            if si >= len(widths) - 2:  # MSA stages
                n_tok = h * h
                layers.append(Layer(f"s{si}b{bi}.qkv", "pw",
                                    macs=n_tok * cin * 3 * cin,
                                    n_weights=3 * cin * cin,
                                    out_elems=n_tok * 3 * cin))
                layers.append(Layer(f"s{si}b{bi}.agg", "dw",
                                    macs=n_tok * 3 * cin * 25,
                                    n_weights=25 * 3 * cin,
                                    out_elems=n_tok * 3 * cin))
                # linear attention matmuls (kv + qkv aggregate), 2 scales
                d = dim_per_head
                heads = cin // d
                mm = 2 * (n_tok * heads * d * d * 2)
                layers.append(Layer(f"s{si}b{bi}.attn_mm", "matmul",
                                    macs=mm, n_weights=0,
                                    out_elems=n_tok * cin * 2))
                layers.append(Layer(f"s{si}b{bi}.proj", "pw",
                                    macs=n_tok * 2 * cin * cin,
                                    n_weights=2 * cin * cin,
                                    out_elems=n_tok * cin))
    layers.append(Layer("head.in", "pw", macs=h * h * cin * cin * 4,
                        n_weights=cin * cin * 4, out_elems=h * h * cin * 4))
    layers.append(Layer("head.fc", "head", macs=cin * 4 * n_classes,
                        n_weights=cin * 4 * n_classes, out_elems=n_classes))
    return layers


EFFICIENTVIT_CONFIGS = {
    "b1-r224": dict(widths=(16, 32, 64, 128, 256), depths=(1, 2, 3, 3, 4),
                    res=224, dim_per_head=16),
    "b1-r256": dict(widths=(16, 32, 64, 128, 256), depths=(1, 2, 3, 3, 4),
                    res=256, dim_per_head=16),
    "b1-r288": dict(widths=(16, 32, 64, 128, 256), depths=(1, 2, 3, 3, 4),
                    res=288, dim_per_head=16),
    "b2-r224": dict(widths=(24, 48, 96, 192, 384), depths=(1, 3, 4, 4, 6),
                    res=224, dim_per_head=32),
}


def fit_buffer_energy(target_uj: float = 26.06, model: str = "b1-r224"):
    """Solve E_WBUF_PER_BIT so Trio-ViT B1-R224 == Table III (one-point fit)."""
    layers = efficientvit_layers(**EFFICIENTVIT_CONFIGS[model])
    base = simulate(layers, "trio", wbuf_per_bit=0.0)
    bits = 0.0
    for layer in layers:
        _, b, _, _ = _layer_cost(layer, "trio")
        bits += b
    return (target_uj * 1e-6 - base.energy_uj * 1e-6) / bits


def set_calibration():
    global E_WBUF_PER_BIT
    E_WBUF_PER_BIT = fit_buffer_energy()
    return E_WBUF_PER_BIT
