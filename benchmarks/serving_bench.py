"""Serving-runtime benchmark: both engines under synthetic arrival traffic.

Emits ``BENCH_serving.json``: for several request arrival rates, the
vision engine's imgs/s and the token engine's tok/s (real wall-clock of
the executed work), plus the *policy-level* queue behavior — p50/p99 queue
latency, batch occupancy, padded-work fraction, and the flush-reason mix
(full batch vs deadline vs drain).

The ``faults`` rows measure fault tolerance instead of raw throughput: the
same traffic runs with a ``serving.faults`` spec injecting failures at a
fixed rate (every Kth decode step / vision batch raising or NaN-poisoning),
and the rows report GOODPUT (completed / submitted) and RECOVERY (the
engine kept serving: every handle resolved, reconciling
``submitted == completed + failed + ...`` with faults firing mid-stream).

Arrivals run on a VIRTUAL clock injected into the shared scheduler core
(serving.scheduler takes ``clock=``), so the deadline-flush policy is
exercised deterministically and independently of how slow this machine's
forward pass happens to be: at low rates batches flush by deadline (queue
latency ~= max_delay_ms, low occupancy); at high rates they flush full
(latency -> 0, occupancy -> 1).  Execution wall time is measured
separately with the real clock for the throughput columns.  The token
engine advances the virtual clock by each decode step's measured wall
time, so its queue latencies reflect real service times.

  PYTHONPATH=src python -m benchmarks.serving_bench [out.json]

``collect(smoke=True)`` is the fast path the test suite exercises.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

import numpy as np

DEFAULT_OUT = Path(__file__).resolve().parent / "BENCH_serving.json"


class VirtualClock:
    """Monotonic seconds under caller control (drives scheduler deadlines)."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt

    def advance_to(self, t: float) -> None:
        self.t = max(self.t, t)


def _arrival_times(n: int, rate_per_s: float, seed: int = 0) -> np.ndarray:
    """Poisson arrivals: n cumulative exponential inter-arrival gaps (s)."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate_per_s, n))


def make_vision_engine(cfg, params, max_batch: int = 8,
                       max_delay_ms: float = 10.0):
    """One (clock, engine) pair reused across bench rows: jitted bucket
    graphs compile once, stats/clock reset between rows."""
    from repro.serving.vision import VisionEngine

    clock = VirtualClock()
    eng = VisionEngine(cfg, params, max_batch=max_batch,
                       max_delay_ms=max_delay_ms, clock=clock.now)
    return clock, eng


def bench_vision(bench_engine, rate_per_s: float, n_images: int,
                 seed: int = 0, warmup: bool = True) -> dict:
    """``bench_engine``: the (clock, engine) pair from make_vision_engine —
    the engine's own max_batch/max_delay_ms ARE the benched policy."""
    clock, eng = bench_engine
    max_batch, max_delay_ms = eng.B, eng.scheduler.policy.max_delay_ms
    eng.stats.reset()
    clock.t = 0.0
    rng = np.random.default_rng(seed)
    res = eng.cfg.img_res
    img = rng.normal(0, 1, (res, res, 3)).astype(np.float32)
    if warmup:
        # compile every pow2 bucket shape, then zero the counters so the
        # wall-clock columns measure steady-state execution
        b = 1
        while b <= max_batch:
            eng.classify(np.broadcast_to(img, (b,) + img.shape))
            b *= 2
        eng.stats.reset()
    wall = 0.0

    def timed_poll():
        nonlocal wall
        t0 = time.perf_counter()
        eng.poll()
        wall += time.perf_counter() - t0

    handles = []
    for t in _arrival_times(n_images, rate_per_s, seed):
        # honor deadlines that fire BETWEEN arrivals (a serving loop would
        # sleep until scheduler.next_deadline(), not until the next request)
        while True:
            nd = eng.scheduler.next_deadline()
            if nd is None or nd >= t:
                break
            clock.advance_to(nd)
            timed_poll()
        clock.advance_to(t)
        timed_poll()
        t0 = time.perf_counter()  # a full batch executes inline on submit
        handles.append(eng.submit(img))
        wall += time.perf_counter() - t0
    # drain the tail through the DEADLINE, not an explicit flush
    while eng.scheduler.pending:
        nd = eng.scheduler.next_deadline()
        clock.advance_to(nd if nd is not None else clock.now())
        timed_poll()
    assert all(h.done() for h in handles)
    s = eng.stats
    return {
        "engine": "vision", "arrival_rate_per_s": rate_per_s,
        "n": n_images, "max_batch": max_batch, "max_delay_ms": max_delay_ms,
        "imgs_per_s_wall": round(n_images / max(wall, 1e-9), 2),
        **s.summary(),
    }


def make_token_engine(cfg, params, max_batch: int = 4, max_len: int = 64,
                      max_delay_ms: float = 0.0):
    from repro.serving.engine import Engine

    clock = VirtualClock()
    eng = Engine(cfg, params, max_batch=max_batch, max_len=max_len,
                 max_delay_ms=max_delay_ms, clock=clock.now)
    return clock, eng


def bench_token(bench_engine, rate_per_s: float, n_requests: int,
                max_new: int = 8, seed: int = 0,
                warmup: bool = True) -> dict:
    """``bench_engine``: the (clock, engine) pair from make_token_engine —
    the engine's own max_batch/max_delay_ms ARE the benched policy."""
    clock, eng = bench_engine
    max_batch = eng.B
    max_delay_ms = eng.scheduler.policy.max_delay_ms
    eng.stats.reset()
    clock.t = 0.0
    rng = np.random.default_rng(seed)
    vocab = eng.cfg.vocab_size
    arrivals = _arrival_times(n_requests, rate_per_s, seed)
    prompts = [rng.integers(0, vocab, int(rng.integers(4, 17)),
                            dtype=np.int32) for _ in range(n_requests)]
    if warmup:
        # compile both ragged-prefill pow2 buckets (<=8 and 16) and the
        # decode step, then zero the counters for steady-state measurement
        for wlen in (4, 16):
            eng.submit(rng.integers(0, vocab, wlen, dtype=np.int32),
                       max_new_tokens=2)
            clock.advance(1.0)  # past any admission deadline
            eng.run()
        eng.stats.reset()
        clock.t = 0.0
    wall = 0.0
    i = 0
    while True:
        while i < n_requests and arrivals[i] <= clock.now():
            eng.submit(prompts[i], max_new_tokens=max_new)
            i += 1
        idle = eng.scheduler.pending == 0 and all(
            s is None for s in eng.slots)
        if idle:
            if i >= n_requests:
                break
            clock.advance_to(arrivals[i])  # sleep until the next arrival
            continue
        t0 = time.perf_counter()
        n_live = eng.step()
        dt = time.perf_counter() - t0
        if n_live:
            wall += dt
            clock.advance(dt)  # service time moves the virtual clock too
        else:
            # queued but not yet due: jump straight to the next event (the
            # admission deadline or the next arrival) — an idle no-op spin
            # is neither served work nor wall time
            targets = [nd for nd in (eng.scheduler.next_deadline(),) if nd]
            if i < n_requests:
                targets.append(arrivals[i])
            clock.advance_to(min(targets) if targets
                             else clock.now() + 1e-3)
    s = eng.stats
    return {
        "engine": "token", "arrival_rate_per_s": rate_per_s,
        "n": n_requests, "max_batch": max_batch, "max_new": max_new,
        "max_delay_ms": max_delay_ms,
        "tok_per_s_wall": round(s.decoded_tokens / max(wall, 1e-9), 2),
        "decoded_tokens": s.decoded_tokens, "engine_steps": s.steps,
        "prefill_batches": s.prefill_batches,
        **s.summary(),
    }


def _fault_fields(eng, spec: str) -> dict:
    """Goodput/recovery accounting appended to a fault-scenario row."""
    s = eng.stats
    return {
        "fault_spec": spec,
        "faults_fired": len(eng.faults.fired),
        "fault_calls": dict(eng.faults.calls),
        "goodput": round(s.completed / max(s.submitted, 1), 4),
        # recovery = the loop survived the injected faults: faults actually
        # fired, yet every submitted handle reached a terminal state
        "recovered": bool(eng.faults.fired) and s.resolved == s.submitted,
    }


def bench_token_faults(cfg, params, spec: str, rate_per_s: float,
                       n_requests: int, max_new: int = 8) -> dict:
    """Token-engine traffic with an injected fault rate: same arrival loop
    as bench_token, but decode steps raise/NaN-poison per ``spec`` and the
    row reports goodput + recovery instead of steady-state throughput."""
    from repro.serving.engine import Engine
    from repro.serving.faults import FaultInjector

    clock = VirtualClock()
    eng = Engine(cfg, params, max_batch=4, max_len=64, max_delay_ms=0.0,
                 clock=clock.now, faults=FaultInjector.parse(spec))
    row = bench_token((clock, eng), rate_per_s, n_requests,
                      max_new=max_new, warmup=False)
    row.update(_fault_fields(eng, spec))
    return row


def bench_vision_faults(cfg, params, spec: str, rate_per_s: float,
                        n_images: int) -> dict:
    """Vision-engine traffic with an injected fault rate (see above)."""
    from repro.serving.faults import FaultInjector
    from repro.serving.vision import VisionEngine

    clock = VirtualClock()
    eng = VisionEngine(cfg, params, max_batch=4, max_delay_ms=20.0,
                       clock=clock.now, faults=FaultInjector.parse(spec))
    row = bench_vision((clock, eng), rate_per_s, n_images, warmup=False)
    row.update(_fault_fields(eng, spec))
    return row


def bench_daemon(cfg, params, n_interactive: int = 4, n_batch: int = 8,
                 max_new: int = 8, max_batch: int = 2,
                 timeout: float = 300.0) -> list:
    """Wall-clock per-SLO-class rows through the background ServingDaemon.

    Unlike the virtual-clock rows above, this measures the REAL serve
    loop: a foreign thread saturates the decode slots with preemptible
    batch traffic, then interactive requests arrive on top — their
    class priority jumps the admission queue and may evict batch
    decodes (restart-from-prefix).  Each row is one SLO class's
    completion-latency distribution (submit -> terminal, daemon
    class_stats), plus the shared engine occupancy/preemption columns
    the accelerator simulator consumes
    (``accel_sim.ServingCalibration``).
    """
    import threading

    from repro.serving.daemon import ServingDaemon
    from repro.serving.engine import Engine

    rng = np.random.default_rng(0)
    vocab = cfg.vocab_size
    eng = Engine(cfg, params, max_batch=max_batch, max_len=64)
    prompts = [rng.integers(0, vocab, int(rng.integers(4, 13)),
                            dtype=np.int32)
               for _ in range(n_interactive + n_batch)]
    results = []
    t0 = time.perf_counter()
    with ServingDaemon(eng) as daemon:
        def submitter():
            for p in prompts[:n_batch]:
                results.append(daemon.submit(p, slo="batch",
                                             max_new_tokens=max_new))

        th = threading.Thread(target=submitter)
        th.start()
        th.join()  # slots saturated before interactive traffic lands
        for p in prompts[n_batch:]:
            results.append(daemon.submit(p, slo="interactive",
                                         max_new_tokens=max_new))
        for r in results:
            r.handle.result(timeout=timeout)
    wall = time.perf_counter() - t0
    s = eng.stats
    assert s.resolved == s.submitted == len(prompts)
    shared = {
        "engine": "daemon", "max_batch": max_batch, "max_new": max_new,
        "wall_s": round(wall, 4),
        "tok_per_s_wall": round(s.decoded_tokens / max(wall, 1e-9), 2),
        "batch_occupancy": round(s.batch_occupancy, 4),
        "preemptions": s.preemptions,
    }
    # shared engine columns LAST: the per-class summary's own batch/
    # occupancy counters are always zero (classes record outcomes and
    # completion latency, not batches) and must not clobber them
    return [{"slo_class": name, **st.summary(), **shared}
            for name, st in daemon.class_stats.items()]


def bench_recovery(cfg, params, n_requests: int = 4, max_new: int = 6,
                   max_batch: int = 2, timeout: float = 300.0) -> list:
    """Crash/hang recovery rows (ISSUE 10): wall-clock MTTR and goodput
    ACROSS a daemon restart, under the journal-backed Supervisor.

    Each scenario arms the FIRST engine build with an uncontained fault
    (``crash@decode`` kills the serve thread, ``hang@decode`` wedges a
    step past the watchdog threshold), submits the workload, and lets the
    supervisor detect -> tear down -> back off -> rebuild -> replay.  The
    row reports restarts, MTTR (detection to daemon-restored,
    ``Supervisor.last_recovery_s``), goodput across the restart
    (completed / submitted), lost handles (journal ``pending`` — must be
    0), and whether every replayed result MATCHES the uninterrupted
    fault-free greedy reference.  Engines are warmed fault-free before
    arming so a cold first step cannot masquerade as a hang.
    """
    import tempfile
    from pathlib import Path as _P

    from repro.serving.engine import Engine
    from repro.serving.faults import FaultInjector, FaultSpec
    from repro.serving.journal import RequestJournal
    from repro.serving.supervisor import RestartPolicy, Supervisor

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 13)),
                            dtype=np.int32) for _ in range(n_requests)]

    ref_eng = Engine(cfg, params, max_batch=max_batch, max_len=64)
    refs = [ref_eng.submit(p, max_new_tokens=max_new) for p in prompts]
    ref_eng.run()
    expected = [r.handle.result() for r in refs]

    rows = []
    for spec in (f"crash@decode:{max_new}", f"hang@decode:{max_new}:30000"):
        builds = []

        def factory(spec=spec, builds=builds):
            eng = Engine(cfg, params, max_batch=max_batch, max_len=64)
            for p in prompts:  # warm every shape fault-free, then arm
                eng.submit(p, max_new_tokens=max_new)
            eng.run()
            if not builds:
                eng.faults = FaultInjector([FaultSpec.parse(spec)])
            builds.append(1)
            return eng

        jpath = _P(tempfile.mkdtemp(prefix="repro-bench-recovery-"),
                   ) / "journal.jsonl"
        sup = Supervisor(
            factory, journal=RequestJournal(jpath),
            policy=RestartPolicy(hang_threshold_s=2.0, backoff_base_s=0.02,
                                 poll_interval_s=0.05))
        t0 = time.perf_counter()
        sup.start()
        handles = [sup.submit(p, request_id=f"bench-{i}",
                              max_new_tokens=max_new)
                   for i, p in enumerate(prompts)]
        outs = [h.result(timeout=timeout) for h in handles]
        wall = time.perf_counter() - t0
        rec = sup.journal.reconcile()
        sup.shutdown(drain=True, timeout=timeout)
        completed = sum(1 for h in handles if h.state == "DONE")
        rows.append({
            "engine": "recovery", "fault_spec": spec, "n": n_requests,
            "max_new": max_new, "max_batch": max_batch,
            "restarts": sup.restarts, "replayed": sup.replayed,
            "mttr_s": round(sup.last_recovery_s or 0.0, 4),
            "wall_s": round(wall, 4),
            "goodput": round(completed / n_requests, 4),
            "lost_handles": rec["pending"],
            "journal_submitted": rec["submitted"],
            "journal_terminal": rec["terminal"],
            "journal_exact": rec["exact"],
            "match_reference": all(
                list(a) == list(b) for a, b in zip(outs, expected)),
            "restart_log": sup.restart_log,
        })
    return rows


def collect(smoke: bool = False) -> dict:
    """All rows.  ``smoke=True`` shrinks traffic to test-suite scale."""
    import jax
    from repro.configs.registry import REDUCED
    from repro.models import get_model

    vcfg = REDUCED["efficientvit-b1-r224"]
    vparams = get_model(vcfg).init(vcfg, jax.random.PRNGKey(0))
    tcfg = REDUCED["qwen1.5-0.5b"]
    tparams = get_model(tcfg).init(tcfg, jax.random.PRNGKey(0))

    n_img, n_req = (8, 5) if smoke else (64, 24)
    warmup = not smoke  # smoke asserts structure, not steady-state timing
    # rates straddle the deadline: ~1 req / max_delay at the low end (most
    # batches flush by deadline), far above it at the high end (full)
    vision_rates = (50.0, 5000.0) if smoke else (20.0, 400.0, 8000.0)
    token_rates = (50.0, 2000.0) if smoke else (20.0, 200.0, 4000.0)

    report = {"smoke": smoke, "unix_time": int(time.time()),
              "backend": jax.default_backend(), "vision": [], "token": []}
    # wall-clock daemon rows (ISSUE 8): per-SLO-class completion latency
    # under mixed interactive/batch traffic through the serve loop
    n_inter, n_bat, d_new = (2, 3, 3) if smoke else (4, 8, 8)
    report["daemon"] = bench_daemon(tcfg, tparams, n_interactive=n_inter,
                                    n_batch=n_bat, max_new=d_new)
    veng = make_vision_engine(vcfg, vparams,
                              max_batch=4 if smoke else 8,
                              max_delay_ms=20.0)
    for i, rate in enumerate(vision_rates):
        report["vision"].append(
            bench_vision(veng, rate, n_img, warmup=warmup and i == 0))
    teng = make_token_engine(tcfg, tparams, max_batch=4, max_delay_ms=10.0)
    for i, rate in enumerate(token_rates):
        report["token"].append(
            bench_token(teng, rate, n_req, max_new=3 if smoke else 8,
                        warmup=warmup and i == 0))
    # fault-rate scenarios: every Kth executor call fails — rows report
    # goodput (completed/submitted) and recovery (all handles resolved).
    # K scales with traffic so the rate actually fires at smoke scale too
    max_new = 3 if smoke else 8
    # token K must exceed max_new: a decode-step raise fails every live
    # slot, so K <= the steps-per-request would zero out goodput entirely
    k_tok, k_vis = (2, 2) if smoke else (12, 3)
    report["faults"] = [
        bench_token_faults(tcfg, tparams, f"raise@decode:*/{k_tok}",
                           token_rates[-1], n_req, max_new=max_new),
        bench_token_faults(tcfg, tparams, f"nan@decode:*/{k_tok + 1}",
                           token_rates[-1], n_req, max_new=max_new),
        bench_vision_faults(vcfg, vparams, f"raise@vision:*/{k_vis}",
                            vision_rates[-1], n_img),
        bench_vision_faults(vcfg, vparams, f"nan@vision:*/{k_vis}",
                            vision_rates[-1], n_img),
    ]
    # crash-recovery scenarios (ISSUE 10): uncontained crash + hung step,
    # supervisor restart, journal replay — MTTR and goodput-across-restart
    report["recovery"] = bench_recovery(
        tcfg, tparams, n_requests=3 if smoke else 4,
        max_new=3 if smoke else 6)
    return report


def main(argv=None):
    out = Path((argv or sys.argv[1:] or [DEFAULT_OUT])[0])
    report = collect(smoke=False)
    out.write_text(json.dumps(report, indent=1, sort_keys=True) + "\n")
    print(f"[serving_bench] wrote {out}")
    for row in report["vision"] + report["token"]:
        tput = row.get("imgs_per_s_wall", row.get("tok_per_s_wall"))
        print(f"  {row['engine']:>6} rate={row['arrival_rate_per_s']:>7}/s "
              f"tput={tput:>9} p50={row['p50_ms']:.2f}ms "
              f"p99={row['p99_ms']:.2f}ms occ={row['batch_occupancy']:.2f} "
              f"flushes={row['flush_reasons']}")
    for row in report["daemon"]:
        print(f"  daemon class={row['slo_class']:<11} "
              f"completed={row['completed']} p50={row['p50_ms']:.1f}ms "
              f"p99={row['p99_ms']:.1f}ms occ={row['batch_occupancy']:.2f} "
              f"preemptions={row['preemptions']}")
    for row in report["faults"]:
        print(f"  {row['engine']:>6} faults={row['fault_spec']:<18} "
              f"goodput={row['goodput']:.2f} "
              f"fired={row['faults_fired']} "
              f"recovered={row['recovered']} "
              f"(completed={row['completed']} failed={row['failed']})")
    for row in report["recovery"]:
        print(f"  recovery fault={row['fault_spec']:<20} "
              f"restarts={row['restarts']} mttr={row['mttr_s']:.2f}s "
              f"goodput={row['goodput']:.2f} lost={row['lost_handles']} "
              f"match_ref={row['match_reference']}")


if __name__ == "__main__":
    main()
