"""Proxy accuracy substrate for the Table I-IV benchmarks.

ImageNet and pretrained EfficientViT weights are unavailable offline, so
quantization-accuracy *numbers* can't be reproduced verbatim; the *trends*
can.  We train a reduced EfficientViT on the synthetic vision task
(data.pipeline.SyntheticVision), cache it under results/, and measure PTQ
accuracy deltas of each scheme on it — the orderings the paper reports
(Table I: PoT << APoT < APoT&Uniform ~ Uniform; Table II: >=4-bit is
accuracy-free for DWConv) are asserted by tests/test_benchmarks.py.
"""
from __future__ import annotations

import pathlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import REDUCED
from repro.data.pipeline import SyntheticVision
from repro.models import get_model
from repro.optim.adamw import AdamW, cosine_schedule

CACHE = pathlib.Path(__file__).resolve().parent.parent / "results" / \
    "proxy_efficientvit.npz"

CFG = REDUCED["efficientvit-b1-r224"]
_STEPS = 300
_BATCH = 32


def _data():
    return SyntheticVision(CFG.n_classes, CFG.img_res, noise=0.7)


def train_proxy(force: bool = False):
    model = get_model(CFG)
    params = model.init(CFG, jax.random.PRNGKey(0))
    if CACHE.exists() and not force:
        data = np.load(CACHE)
        flat, treedef = jax.tree_util.tree_flatten(params)
        return jax.tree_util.tree_unflatten(
            treedef, [jnp.asarray(data[f"leaf_{i}"]) for i in range(len(flat))])
    ds = _data()
    opt = AdamW(lr=cosine_schedule(2e-3, 10, _STEPS))
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, x, y):
        def loss_fn(p):
            lg = model.forward(CFG, p, x).astype(jnp.float32)
            return jnp.mean(-jax.nn.log_softmax(lg)[jnp.arange(x.shape[0]), y])

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    for s in range(_STEPS):
        x, y = ds.batch(s, _BATCH)
        params, opt_state, loss = step(params, opt_state,
                                       jnp.asarray(x), jnp.asarray(y))
    CACHE.parent.mkdir(parents=True, exist_ok=True)
    flat, _ = jax.tree_util.tree_flatten(params)
    np.savez(CACHE, **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(flat)})
    return params


def accuracy(params, n_batches: int = 8, seed0: int = 10_000) -> float:
    model = get_model(CFG)
    ds = _data()
    fwd = jax.jit(lambda p, x: model.forward(CFG, p, x))
    correct = total = 0
    for b in range(n_batches):
        x, y = ds.batch(seed0 + b, _BATCH)
        pred = np.asarray(jnp.argmax(fwd(params, jnp.asarray(x)), -1))
        correct += int((pred == y).sum())
        total += len(y)
    return correct / total
