"""Benchmark orchestrator: one function per paper table + the roofline
report.  Prints ``name,value,derived`` CSV rows (kernel micro-latencies are
not meaningful on the CPU interpret path; the accelerator simulator and the
dry-run artifacts carry the performance content)."""
from __future__ import annotations

import time


def main() -> None:
    from . import accel_sim, kernel_bench, roofline, tables

    accel_sim.set_calibration()
    print("name,value,derived")
    kernel_bench.print_report(kernel_bench.write_report())
    t0 = time.time()
    for fn in (tables.table1_schemes, tables.table2_bits,
               tables.table3_energy, tables.table4_ablation,
               tables.table5_accel, tables.table6_units):
        for name, value, derived in fn():
            print(f"{name},{value},{derived}")
    rows = roofline.write_reports()
    n_ok = sum(1 for r in rows if r.get("status") == "ok")
    n_skip = sum(1 for r in rows if r.get("status") == "skipped")
    for r in rows:
        if r.get("status") == "ok":
            opt = r.get("opt_fraction")
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
                  f"{r['roofline_fraction']:.4f},"
                  f"{r['dominant']}"
                  + (f" opt={opt:.4f} ({r['speedup']:.1f}x)" if opt else ""))
    print(f"summary/roofline_cells,{n_ok},{n_skip} skipped")
    print(f"summary/total_seconds,{time.time() - t0:.1f},")


if __name__ == "__main__":
    main()
