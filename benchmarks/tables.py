"""Paper-table benchmarks (one function per table; run.py orchestrates).

Accuracy columns use the trained synthetic-vision proxy (see proxy_model.py
for why); energy/latency/EDP columns use the calibrated accelerator
simulator (accel_sim.py).  Each function returns a list of CSV rows
(name, value, derived) and writes a markdown block under results/tables/.
"""
from __future__ import annotations

import pathlib
import time

from repro.core import policy as pol
from repro.core.apply import fake_quant_model
from repro.models import get_model

from . import accel_sim as A
from .proxy_model import CFG, accuracy, train_proxy

OUT = pathlib.Path(__file__).resolve().parent.parent / "results" / "tables"

_COMPUTE_KINDS = {pol.KIND_DENSE}
_MEMORY_KINDS = {pol.KIND_DWCONV}


def _write(name: str, header, rows):
    OUT.mkdir(parents=True, exist_ok=True)
    lines = ["| " + " | ".join(header) + " |",
             "|" + "|".join(["---"] * len(header)) + "|"]
    for r in rows:
        lines.append("| " + " | ".join(str(x) for x in r) + " |")
    (OUT / f"{name}.md").write_text("\n".join(lines) + "\n")
    return rows


def table1_schemes():
    """Table I: accuracy of compute-intensive weights under each scheme.
    Paper trend: Uniform(-0.02) ~ APoT&Uniform(-0.11) > APoT(-0.19) >>
    PoT(-1.17)."""
    model = get_model(CFG)
    params = train_proxy()
    fp = accuracy(params)
    rows = [("float", round(fp, 4), 0.0)]
    for scheme, bits in [("uniform", 8), ("pot", 3), ("apot", 8), ("m2q", 8)]:
        t0 = time.time()
        fq = fake_quant_model(params, model.QUANT_RULES, scheme=scheme,
                              bits=bits, kinds=_COMPUTE_KINDS)
        acc = accuracy(fq)
        rows.append((scheme, round(acc, 4), round(fp - acc, 4)))
    _write("table1_schemes", ("scheme", "top1", "drop"), rows)
    return [("table1/" + r[0], r[1], r[2]) for r in rows]


def table2_bits():
    """Table II: DWConv weight bit-width sweep; >=4 bits is accuracy-free."""
    model = get_model(CFG)
    params = train_proxy()
    fp = accuracy(params)
    rows = [("float", round(fp, 4), 0.0)]
    for b in (2, 3, 4, 5, 6, 7, 8):
        fq = fake_quant_model(params, model.QUANT_RULES, scheme="uniform",
                              bits=b, kinds=_MEMORY_KINDS)
        acc = accuracy(fq)
        rows.append((f"{b}bit", round(acc, 4), round(fp - acc, 4)))
    _write("table2_bits", ("bits", "top1", "drop"), rows)
    return [("table2/" + r[0], r[1], r[2]) for r in rows]


def table3_energy():
    """Table III: computational energy (uJ) + proxy accuracy per method,
    across the four EfficientViT variants.  Paper reference values inline."""
    A.set_calibration()
    paper = {  # (trio, autovit, ours) uJ from Table III
        "b1-r224": (26.06, 16.13, 17.85), "b1-r256": (34.03, 21.07, 23.31),
        "b1-r288": (43.07, 26.66, 29.50), "b2-r224": (80.58, 49.88, 55.64),
    }
    model = get_model(CFG)
    params = train_proxy()
    accs = {}
    for label, scheme in [("trio", "uniform"), ("autovit", "pot_mix"),
                          ("ours", "m2q")]:
        fq = fake_quant_model(params, model.QUANT_RULES, scheme=scheme,
                              kinds=_COMPUTE_KINDS)
        if label == "ours":  # ours also quantizes DWConv to 4 bits
            fq = fake_quant_model(fq, model.QUANT_RULES, scheme="uniform",
                                  bits=4, kinds=_MEMORY_KINDS)
        accs[label] = accuracy(fq)
    rows = []
    for name, cfgkw in A.EFFICIENTVIT_CONFIGS.items():
        layers = A.efficientvit_layers(**cfgkw)
        for label, method in [("trio", "trio"), ("autovit", "autovit"),
                              ("ours", "m2q")]:
            sim = A.simulate(layers, method)
            ref = paper[name][["trio", "autovit", "ours"].index(label)]
            rows.append((name, label, round(sim.energy_uj, 2), ref,
                         round(accs[label], 4)))
    _write("table3_energy",
           ("model", "method", "energy_uJ(sim)", "energy_uJ(paper)",
            "proxy_top1"), rows)
    return [(f"table3/{r[0]}/{r[1]}", r[2], r[3]) for r in rows]


def table4_ablation():
    """Table IV: M2Q applied to FFN(MBConv)-only / attention-only / all."""
    A.set_calibration()
    model = get_model(CFG)
    params = train_proxy()
    groups = {
        "ffn_only": r"w_pw\d",
        "attention_only": r"(w_qkv|w_proj|w_agg)",
        "all": r".",
    }
    is_attn = lambda l: ("qkv" in l.name or "proj" in l.name
                         or "agg" in l.name or "attn" in l.name)
    is_ffn = lambda l: ("pw" in l.name.split(".")[-1] or l.kind == "dw"
                        or "stem" in l.name or "head" in l.name)
    selectors = {"ffn_only": is_ffn, "attention_only": is_attn,
                 "all": lambda l: True}
    layers = A.efficientvit_layers(**A.EFFICIENTVIT_CONFIGS["b1-r224"])
    rows = [("none(trio)", round(A.simulate(layers, "trio").energy_uj, 2),
             round(accuracy(train_proxy()), 4))]
    for gname, pat in groups.items():
        fq = fake_quant_model(params, model.QUANT_RULES, scheme="m2q",
                              kinds=_COMPUTE_KINDS, path_filter=pat)
        fq = fake_quant_model(fq, model.QUANT_RULES, scheme="uniform", bits=4,
                              kinds=_MEMORY_KINDS, path_filter=pat)
        sel = selectors[gname]
        sim = A.simulate(layers, method_for=lambda l: "m2q" if sel(l)
                         else "trio")
        rows.append((gname, round(sim.energy_uj, 2), round(accuracy(fq), 4)))
    _write("table4_ablation", ("layers", "energy_uJ", "proxy_top1"), rows)
    return [("table4/" + r[0], r[1], r[2]) for r in rows]


def table5_accel():
    """Table V: accelerator-level comparison.  Trio/CPU/GPU rows are
    paper-reported context; 'ours' is simulated."""
    A.set_calibration()
    layers = A.efficientvit_layers(**A.EFFICIENTVIT_CONFIGS["b1-r224"])
    ours = A.simulate(layers, "m2q")
    paper_rows = [
        ("cpu(paper)", 54.7, 5.0, 19.0, None, None),
        ("jetson(paper)", 41.9, 4.2, 24.8, None, None),
        ("trio-asic(paper)", 1978.0, 757.9, 0.53, 8.11, 4.3),
        ("ours(paper)", 2150.0, 2687.5, 0.48, 1.83, 0.88),
    ]
    power_w = ours.energy_mj_total / ours.latency_ms  # mJ/ms = W
    ours_row = ("ours(sim)", round(ours.throughput_gops, 0),
                round(ours.throughput_gops / power_w, 1),
                round(ours.latency_ms, 3),
                round(ours.energy_mj_total, 2), round(ours.edp_mj_ms, 2))
    rows = paper_rows + [ours_row]
    trio_edp = 4.3
    edp_saving = 1 - ours.edp_mj_ms / trio_edp
    rows.append(("edp_saving_vs_trio", round(edp_saving * 100, 1), "%",
                 "paper: 80%", "", ""))
    _write("table5_accel",
           ("platform", "GOPS", "GOPS/W", "latency_ms", "energy_mJ",
            "EDP_mJ_ms"), rows)
    return [("table5/" + str(r[0]), r[1], r[3]) for r in rows]


def table6_units():
    """Table VI: unit energies (constants) + weight-buffer bits for B1 under
    8-bit uniform vs M2Q storage, computed from the actual layer inventory."""
    layers = A.efficientvit_layers(**A.EFFICIENTVIT_CONFIGS["b1-r224"])
    bits_trio = sum(l.n_weights * 8 for l in layers)
    bits_ours = 0
    for l in layers:
        if l.kind == "dw":
            bits_ours += l.n_weights * 4
        else:
            bits_ours += l.n_weights // 2 * 8 + l.n_weights // 2 * 7
    rows = [
        ("mult_8x8_trio_fJ", round(A.E_MAC88_TRIO * 1e15, 1), ""),
        ("mult_ps_ours_fJ", round(A.E_MAC88_OURS * 1e15, 1), ""),
        ("shifter_unit_fJ", round(A.E_APOT_MAC * 1e15, 1), ""),
        ("weight_bits_trio_Mb", round(bits_trio / 1e6, 2), ""),
        ("weight_bits_ours_Mb", round(bits_ours / 1e6, 2),
         f"{(1 - bits_ours / bits_trio) * 100:.1f}% smaller"),
    ]
    _write("table6_units", ("unit", "value", "note"), rows)
    return [("table6/" + r[0], r[1], r[2]) for r in rows]
