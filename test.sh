#!/usr/bin/env bash
# Canonical tier-1 test entry point (documented in ROADMAP.md).
#
# Fast loop: ./test.sh -m "not slow"   (skips the subprocess dry-runs;
# the suite includes the repo-hygiene check that fails on tracked
# *.pyc/__pycache__ paths — see tests/test_recipe.py).
#
# Env setup follows SNIPPETS.md (olmax run.sh): fp64 is *allowed* but the
# default dtype stays 32-bit, and the host platform exposes exactly one
# virtual device (the sharded dry-run tests fork subprocesses that set
# their own 16-device world before jax initializes).
set -euo pipefail
cd "$(dirname "$0")"

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="--xla_force_host_platform_device_count=1${XLA_FLAGS:+ $XLA_FLAGS}"
export JAX_ENABLE_X64=1          # allow fp64
export JAX_DEFAULT_DTYPE_BITS=32 # ..but don't enforce it

exec python -m pytest -x -q "$@"
