"""END-TO-END DRIVER (the paper is an inference paper): PTQ-quantize a
small LM with M2Q and serve a stream of batched requests through the
continuous-batching engine — async admission queue (deadline-based prefill
coalescing on the shared scheduler core), prefill, decode, slot reuse,
sampling.

Kernel dispatch is controlled by three env-var process defaults, all read
only in repro.kernels.ops (a scoped DispatchConfig / engine ``dispatch=``
always wins over them):

  REPRO_PALLAS_DISPATCH=1/0       QTensor matmuls (nn.dense + 1x1 PWConvs)
  REPRO_PALLAS_CONV_DISPATCH=1/0  conv paths (falls back to the dense var)
  REPRO_PALLAS_ATTN_DISPATCH=1/0  int8 attention kernels: the MSA ReLU
                                  linear attention and, with an int8 KV
                                  cache (--arch with kv_cache_dtype=int8),
                                  this engine's per-step decode attention
                                  (falls back to the dense var)

Fault tolerance demo: pass ``--fault-spec`` (same grammar as the
``REPRO_FAULT_SPEC`` env var, e.g. ``raise@decode:*/6``) to inject
deterministic failures into the decode loop — affected requests fail
through their handles, everything else completes, and the outcome
counters reconcile at the end.  Prefer ``raise`` over ``nan`` here: this
engine is fully quantized, and activation quantization can launder a
cache NaN into finite garbage before the logits check sees it (see
docs/serving.md, "Detection boundary").

Daemon demo: ``--daemon`` serves the same quantized engine through the
background wall-clock serve loop instead of the inline ``run()`` —
batch-tier requests saturate the slots, interactive requests jump the
queue (and may preempt batch decodes), and ``--stream`` prints the first
interactive request's tokens as they decode through the streaming Handle
API.  See docs/serving.md, "Running the daemon"; the full CLI (SLO
mix, smoke mode, multi-host mesh launch) is ``repro.launch.daemon``.

Beyond the daemon: production serving wraps the daemon in a
``serving.supervisor.Supervisor`` — request journal + replay-on-restart,
hung-step watchdog, crash-loop backoff, health/readiness probes — see
docs/serving.md, "Supervision & recovery", and the
``repro.launch.daemon --health-file`` / ``--recovery-smoke`` paths.

  PYTHONPATH=src python examples/serve_quantized.py [--arch qwen1.5-0.5b]
  PYTHONPATH=src python examples/serve_quantized.py --fault-spec raise@decode:*/6
  PYTHONPATH=src python examples/serve_quantized.py --daemon --stream
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.registry import REDUCED
from repro.launch.serve import quantize_for_serving
from repro.models import get_model
from repro.serving.faults import FaultInjector


def serve_daemon(eng, args):
    """--daemon: the same quantized engine behind the background
    wall-clock serve loop — batch tier saturates the slots, interactive
    tier jumps the queue (and may preempt), the first interactive
    request streams token by token (docs/serving.md, 'Running the
    daemon')."""
    from repro.serving.daemon import ServingDaemon

    rng = np.random.default_rng(7)
    cfg = eng.cfg
    n_batch = max(1, args.requests - args.requests // 3)
    n_inter = args.requests - n_batch
    reqs = []
    t0 = time.time()
    with ServingDaemon(eng) as daemon:
        for _ in range(n_batch):
            plen = int(rng.integers(4, 24))
            reqs.append(daemon.submit(
                rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
                slo="batch", max_new_tokens=args.max_new))
        streamed = []
        first = daemon.submit(
            rng.integers(0, cfg.vocab_size, 8, dtype=np.int32),
            slo="interactive", max_new_tokens=args.max_new, stream=True)
        for _ in range(max(0, n_inter - 1)):
            reqs.append(daemon.submit(
                rng.integers(0, cfg.vocab_size, 8, dtype=np.int32),
                slo="interactive", max_new_tokens=args.max_new))
        for tok in first.handle.tokens(timeout=300.0):
            streamed.append(tok)
            if args.stream:
                print(f"      stream tok={tok}", flush=True)
        reqs.append(first)
        for r in reqs:
            r.handle.result(timeout=300.0)
    dt = time.time() - t0
    assert streamed == first.handle.result()
    stats = eng.stats
    assert stats.resolved == stats.submitted == len(reqs)
    print(f"      daemon served {stats.completed} requests in {dt:.1f}s "
          f"(streamed {len(streamed)} tokens wall-clock, "
          f"preemptions={stats.preemptions})")
    for name, row in sorted(daemon.stats_summary()["classes"].items()):
        print(f"      class={name}: completed={row['completed']} "
              f"p50={row['p50_ms']:.1f}ms p99={row['p99_ms']:.1f}ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-delay-ms", type=float, default=5.0,
                    help="admission deadline: requests coalesce into "
                         "bigger prefill batches until the oldest ages out")
    ap.add_argument("--fault-spec", default=None,
                    help="inject deterministic faults (KIND@SITE:WHEN, "
                         "e.g. raise@decode:*/6) to demo containment")
    ap.add_argument("--daemon", action="store_true",
                    help="serve through the background wall-clock "
                         "ServingDaemon (SLO classes, streaming) instead "
                         "of the inline run() loop — docs/serving.md")
    ap.add_argument("--stream", action="store_true",
                    help="with --daemon: print the first interactive "
                         "request's tokens as they decode")
    args = ap.parse_args()

    cfg = REDUCED[args.arch]
    model = get_model(cfg)
    print(f"[1/3] init {cfg.name}")
    params = model.init(cfg, jax.random.PRNGKey(0))

    print("[2/3] PTQ: calibrate + apply M2Q (one-call recipe API)")
    qm = quantize_for_serving(cfg, params)
    report = qm.report
    total_bits = sum(r.bits * np.prod(r.shape) for r in report)
    total_w = sum(np.prod(r.shape) for r in report)
    print(f"      {len(report)} layers quantized; "
          f"avg {total_bits / total_w:.2f} bits/weight "
          f"({sum(1 for r in report if r.decision == 'mixed')} mixed, "
          f"{sum(1 for r in report if r.decision == 'lowbit')} low-bit)")

    print("[3/3] serve with continuous batching (async admission queue)")
    faults = (FaultInjector.parse(args.fault_spec)
              if args.fault_spec else None)
    eng = qm.serve(max_batch=4, max_len=96, max_delay_ms=args.max_delay_ms,
                   faults=faults)
    if args.daemon:
        return serve_daemon(eng, args)
    rng = np.random.default_rng(7)
    reqs = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        # submit returns immediately; each request also carries a handle
        # (req.handle) that resolves when its tokens are ready
        reqs.append(eng.submit(
            rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
            max_new_tokens=args.max_new,
            temperature=0.8 if i % 2 else 0.0))
    t0 = time.time()
    stats = eng.run()  # admission flushes by deadline/full batch, no flush()
    dt = time.time() - t0
    # every handle must RESOLVE (succeed or fail) — the engine never wedges
    assert all(r.handle.done() for r in reqs)
    if faults is not None:
        fired = ", ".join(f"{k}@{s}#{n}" for s, n, k in faults.fired)
        print(f"      injected: {fired or '(no fault fired)'}")
        print(f"      outcomes: {stats.completed} completed, "
              f"{stats.failed} failed "
              f"(resolved {stats.resolved}/{stats.submitted})")
    else:
        assert all(r.done for r in reqs)
    print(f"      served {stats.finished} requests, "
          f"{stats.decoded_tokens} tokens in {dt:.1f}s "
          f"({stats.decoded_tokens / dt:.1f} tok/s, "
          f"{stats.steps} engine steps)")
    print(f"      queue p50={stats.p50_ms:.2f}ms p99={stats.p99_ms:.2f}ms "
          f"prefill-occupancy={stats.batch_occupancy:.2f} "
          f"flushes={stats.flush_reasons}")
    ok = [r for r in reqs if r.done]
    if ok:
        print("      sample:", ok[0].handle.result())


if __name__ == "__main__":
    main()
