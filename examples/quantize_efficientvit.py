"""The paper's own pipeline end to end on EfficientViT: train a (reduced)
hybrid ViT on the synthetic vision task, apply the two-level mixed
quantization exactly as Sec. III prescribes (mixed uniform/APoT on
PWConv/MatMul weights, 4-bit on DWConvs), measure the accuracy delta, and
price the result on the calibrated accelerator simulator (Tables III/V
scope).

  PYTHONPATH=src:. python examples/quantize_efficientvit.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import numpy as np

from benchmarks import accel_sim as A
from benchmarks.proxy_model import CFG, accuracy, train_proxy
from repro.core import policy as pol
from repro.core.apply import fake_quant_model
from repro.models import get_model


def main():
    model = get_model(CFG)
    print("[1/3] train (or load cached) proxy EfficientViT")
    params = train_proxy()
    acc_fp = accuracy(params)

    print("[2/3] apply M2Q (paper Sec. III)")
    q = fake_quant_model(params, model.QUANT_RULES, scheme="m2q",
                         kinds={pol.KIND_DENSE})
    q = fake_quant_model(q, model.QUANT_RULES, scheme="uniform", bits=4,
                         kinds={pol.KIND_DWCONV})
    acc_q = accuracy(q)
    print(f"      top-1: float {acc_fp:.4f} -> M2Q {acc_q:.4f} "
          f"(drop {acc_fp - acc_q:+.4f}; paper reports ~0.29% avg)")

    print("[3/3] accelerator cost (calibrated cycle/energy model)")
    A.set_calibration()
    layers = A.efficientvit_layers(**A.EFFICIENTVIT_CONFIGS["b1-r224"])
    trio = A.simulate(layers, "trio")
    ours = A.simulate(layers, "m2q")
    print(f"      Trio-ViT (uniform W8A8): {trio.energy_uj:.1f} uJ, "
          f"{trio.latency_ms:.3f} ms")
    print(f"      M2-ViT  (mixed + 4-bit): {ours.energy_uj:.1f} uJ, "
          f"{ours.latency_ms:.3f} ms  "
          f"-> {100 * (1 - ours.energy_uj / trio.energy_uj):.1f}% comp-energy"
          f" saving (paper: 31.5%)")
    edp_saving = 1 - ours.edp_mj_ms / 4.3  # paper-reported Trio EDP
    print(f"      EDP saving vs Trio-ViT: {100 * edp_saving:.0f}% "
          f"(paper: 80%)")


if __name__ == "__main__":
    main()
