"""The paper's own pipeline end to end on EfficientViT: train a (reduced)
hybrid ViT on the synthetic vision task, then run REAL two-level mixed
quantization exactly as Sec. III prescribes — through the one-call recipe
API: ``quantize()`` bundles PTQ activation calibration, per-filter MSE
scheme selection (Eq. 6), and QTensor weights (mixed uniform8/APoT on
PWConv/MatMul, packed 4-bit on DWConvs) into a persistable
``QuantizedModel`` artifact, which is saved, reloaded (no re-quantization),
and served through the batched vision engine.  The quantized forward
executes the M2Q conv/matmul hot path (fused Pallas kernels on TPU /
REPRO_PALLAS_DISPATCH=1 / a scoped kernels.ops.DispatchConfig; pure-XLA
QTensor int paths otherwise — never a f32 dequantized-weight convolution
for PWConvs).  Finally the result is priced on the calibrated accelerator
simulator (Tables III/V scope).

  PYTHONPATH=src:. python examples/quantize_efficientvit.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

import jax
import numpy as np

from benchmarks import accel_sim as A
from benchmarks.proxy_model import CFG, _data, accuracy, train_proxy
from repro.recipe import QuantizedModel, quantize

_CALIB_BATCHES = 4
_BATCH = 32


def main():
    print("[1/6] train (or load cached) proxy EfficientViT")
    params = train_proxy()
    acc_fp = accuracy(params)

    print("[2/6] one-call M2Q: calibrate (Sec. V-A) + quantize (Sec. III)")
    ds = _data()
    batches = [jax.numpy.asarray(ds.batch(20_000 + i, _BATCH)[0])
               for i in range(_CALIB_BATCHES)]
    qm = quantize(CFG, params, "m2q-w8a8", calib_batches=batches)
    print(f"      recorded max-abs for {qm.provenance['calib_sites']} "
          "activation sites")
    n_mixed = sum(r.decision.startswith("mixed") for r in qm.report)
    n_lowbit = sum(r.decision == "lowbit" for r in qm.report)
    bits = [r.bits for r in qm.report]
    print(f"      {len(qm.report)} quantized layers: {n_mixed} mixed "
          f"(uniform8/APoT), {n_lowbit} low-bit; "
          f"avg stored bits/weight {np.mean(bits):.2f}")
    acc_q = accuracy(qm.params)
    print(f"      top-1: float {acc_fp:.4f} -> M2Q {acc_q:.4f} "
          f"(drop {acc_fp - acc_q:+.4f}; paper reports ~0.29% avg)")

    print("[3/6] save -> load the artifact (no re-quantization)")
    with tempfile.TemporaryDirectory() as d:
        qm.save(d)
        qm2 = QuantizedModel.load(d)
    same = all(jax.tree.leaves(jax.tree.map(
        lambda a, b: bool(np.array_equal(np.asarray(a), np.asarray(b))),
        qm.params, qm2.params)))
    print(f"      round-trip bitwise-identical tree: {same}")

    print("[4/6] async vision serving (deadline flush) on the loaded tree")
    # the engine sits on the shared scheduler core: submit() returns a
    # handle immediately, and batches execute when they FILL or when the
    # oldest request's age exceeds max_delay_ms — no explicit flush()
    eng = qm2.serve(max_batch=8, max_delay_ms=15.0)
    rng = np.random.default_rng(0)
    handles = [eng.submit(rng.normal(0, 1, (CFG.img_res, CFG.img_res, 3))
                          .astype(np.float32)) for _ in range(12)]
    while not all(h.done() for h in handles):
        eng.poll()  # full batches already ran inline; the tail of 4 images
        #             executes here once the 15 ms deadline fires
    logits = np.stack([h.result() for h in handles])
    assert logits.shape == (12, CFG.n_classes)
    print(f"      {eng.stats.images} images in {eng.stats.batches} batches, "
          f"buckets {sorted(eng.stats.buckets_used)}, "
          f"{eng.stats.padded_images} pad rows, "
          f"flushes {eng.stats.flush_reasons}, "
          f"queue p50 {eng.stats.p50_ms:.1f} ms")

    print("[5/6] accelerator cost (calibrated cycle/energy model)")
    A.set_calibration()
    layers = A.efficientvit_layers(**A.EFFICIENTVIT_CONFIGS["b1-r224"])
    trio = A.simulate(layers, "trio")
    ours = A.simulate(layers, "m2q")
    print(f"      Trio-ViT (uniform W8A8): {trio.energy_uj:.1f} uJ, "
          f"{trio.latency_ms:.3f} ms")
    print(f"      M2-ViT  (mixed + 4-bit): {ours.energy_uj:.1f} uJ, "
          f"{ours.latency_ms:.3f} ms  "
          f"-> {100 * (1 - ours.energy_uj / trio.energy_uj):.1f}% comp-energy"
          f" saving (paper: 31.5%)")
    edp_saving = 1 - ours.edp_mj_ms / 4.3  # paper-reported Trio EDP
    print(f"      EDP saving vs Trio-ViT: {100 * edp_saving:.0f}% "
          f"(paper: 80%)")
    # calibrate the latency model against MEASURED kernel wall-clock
    # (BENCH_kernels.json fused-vs-f32 conv rows + MSA attention rows)
    cal = A.KernelCalibration.from_bench_json()
    ours_cal = A.simulate(layers, "m2q", kernel_cal=cal)
    print(f"      measured-kernel calibration ({cal.backend}: "
          f"pw x{cal.pw_speedup:.2f}, dw x{cal.dw_speedup:.2f}, "
          f"attn x{cal.attn_speedup:.2f}): "
          f"{ours_cal.latency_ms:.3f} ms, EDP {ours_cal.edp_mj_ms:.2f} "
          f"mJ*ms (ideal {ours.edp_mj_ms:.2f})")
    print("[6/6] done")


if __name__ == "__main__":
    main()
