"""Quickstart: the M2Q two-level mixed quantization pipeline in ~50 lines.

  PYTHONPATH=src python examples/quickstart.py

1. build a small LM, 2. one-call recipe quantization (PTQ calibration +
mixed uniform8/APoT on compute-intensive weights + 4-bit on
memory-intensive ones, bundled by the "m2q-w8a8" preset), 3. compare float
vs quantized outputs, 4. run the fused Pallas m2q kernel against its
oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import REDUCED
from repro.models import get_model
from repro.recipe import quantize

cfg = REDUCED["qwen1.5-0.5b"]
model = get_model(cfg)
params = model.init(cfg, jax.random.PRNGKey(0))
toks = jnp.asarray(np.random.default_rng(0).integers(
    0, cfg.vocab_size, (2, 32), dtype=np.int32))

# 1. float reference
logits_fp = model.forward(cfg, params, toks)

# 2. one call: PTQ calibration (paper Sec. V-A: offline, no fine-tuning)
# + Eq. 6 scheme selection + QTensor quantization.  The recipe resolver
# pins the mixed decision on this demo's tiny (memory-bound-everywhere)
# widths, so the mixed-scheme path is visible without threshold hacks.
qm = quantize(cfg, params, "m2q-w8a8", calib_batches=[toks])
print(f"calibrated {qm.provenance['calib_sites']} activation ranges")
for r in qm.report[:4]:
    print(f"  {r.path:24s} {r.kind:10s} -> {r.decision:7s} "
          f"{r.bits:.1f} bits  (apot:{r.n_apot} uniform:{r.n_uniform})")

# 3. quantized forward (artifact method; identical to model.forward on
# qm.params)
logits_q = qm.forward(toks)
rel = float(jnp.linalg.norm(logits_q - logits_fp)
            / jnp.linalg.norm(logits_fp))
print(f"quantized-vs-float relative error: {rel:.4f}")

# 4. the fused mixed-scheme Pallas kernel vs its pure-jnp oracle.
# The merged permutation-free layout: one byte per weight in original
# filter order, float activations in (quantization fused into the kernel
# prologue), one output array out — no concatenate/gather epilogue.
from repro.core import QM2Q, select_schemes
from repro.kernels import ops
from repro.kernels import ref as kref

w = jnp.asarray(np.random.default_rng(1).normal(0, 0.05, (128, 128)),
                jnp.float32)
x = jnp.asarray(np.random.default_rng(2).normal(0, 1, (16, 128)), jnp.float32)
asn = select_schemes(w, ratio=0.5)
qt = QM2Q.quantize(w, asn.apot_idx, asn.uniform_idx,
                   act_max_abs=jnp.max(jnp.abs(x)))
y = ops.m2q_matmul_op(x, qt.act_scale, qt.payload, qt.u_scale.reshape(-1),
                      qt.u_zp.reshape(-1), qt.a_scale.reshape(-1),
                      interpret=True)
r = kref.m2q_merged_ref(x, qt.act_scale, qt.payload, qt.u_scale.reshape(-1),
                        qt.u_zp.reshape(-1), qt.a_scale.reshape(-1))
print("fused kernel max|err| vs oracle:", float(jnp.max(jnp.abs(y - r))))
print("quickstart OK")
