"""Train a small LM end-to-end with the fault-tolerant loop: deterministic
data, async checkpoints, preemption-safe resume.  (The ~100M-scale run is
the same code path via ``launch.train --arch <id>`` on real hardware; on
this CPU container the default is a few-M-param config so a few hundred
steps finish in minutes.)

  PYTHONPATH=src python examples/train_small_lm.py [--steps 200]
"""
import argparse
import json
import tempfile
from pathlib import Path

from repro.configs.registry import REDUCED
from repro.train.loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    args = ap.parse_args()

    cfg = REDUCED[args.arch].replace(vocab_size=256)
    workdir = Path(tempfile.mkdtemp(prefix="repro_train_"))
    tc = TrainConfig(steps=args.steps, global_batch=args.batch,
                     seq_len=args.seq, lr=1e-3, warmup=20,
                     ckpt_dir=str(workdir / "ckpt"),
                     ckpt_every=50,
                     metrics_path=str(workdir / "metrics.jsonl"))
    _, _, info = train(cfg, tc)
    losses = info["losses"]
    print(f"steps={len(losses)} loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(ckpts in {workdir}/ckpt)")
    assert losses[-1] < losses[0], "loss should decrease"
    for line in Path(tc.metrics_path).read_text().splitlines()[-3:]:
        print(" ", json.loads(line))


if __name__ == "__main__":
    main()
