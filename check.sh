#!/usr/bin/env bash
# Pre-merge check: lint + the fast test suite in one command.
#
#   ./check.sh            lint src/ then run ./test.sh -m "not slow"
#   ./check.sh --lint-only
#
# Lint = pyflakes over src/ (when installed — the container may not have
# it; we do not install packages) plus a stdlib compileall pass, which
# catches syntax errors in EVERY file including ones the fast suite never
# imports.  The full tier-1 gate remains ./test.sh with no -m filter.
set -euo pipefail
cd "$(dirname "$0")"

echo "== compileall (syntax, all of src/ + tests/ + benchmarks/ + examples/)"
python -m compileall -q src tests benchmarks examples

if python -c "import pyflakes" 2>/dev/null; then
    echo "== pyflakes src/"
    python -m pyflakes src
else
    echo "== pyflakes not installed; skipping (compileall still ran)"
fi

if [[ "${1:-}" == "--lint-only" ]]; then
    exit 0
fi

echo "== fast suite (./test.sh -m 'not slow')"
exec ./test.sh -m "not slow"
