#!/usr/bin/env bash
# Pre-merge check: lint + qlint + the fast test suite in one command.
#
#   ./check.sh              lint src/, qlint HLO sweep, ./test.sh -m "not slow"
#   ./check.sh --lint-only  lint stages only (compileall + pyflakes)
#   ./check.sh --strict     CI mode: a missing pyflakes FAILS instead of
#                           being skipped (the dev container may not ship
#                           it; CI must)
#
# Lint = pyflakes over src/ (hard gate under --strict) plus a stdlib
# compileall pass, which catches syntax errors in EVERY file including
# ones the fast suite never imports.  qlint = the rule-based HLO verifier
# (docs/qlint.md) diffed against the committed baseline ledger — it fails
# on NEW violations AND (--fail-on-gone) on stale ledger rows, keeping
# the ratchet tight in both directions.  The daemon smoke stage streams
# one real wall-clock request through the background serve loop
# (docs/serving.md); the crash-recovery smoke kills that loop with an
# injected uncontained crash and proves the supervisor + journal replay
# it back to exact reconciliation (docs/serving.md, "Supervision &
# recovery").  The autotune sweep smoke asserts the committed
# CI-shape cache is complete — serving traces must be pure cache hits,
# zero tuning probes (docs/kernels.md).  The full tier-1 gate remains
# ./test.sh with no -m filter.
set -euo pipefail
cd "$(dirname "$0")"

STRICT=0
LINT_ONLY=0
for arg in "$@"; do
    case "$arg" in
        --strict) STRICT=1 ;;
        --lint-only) LINT_ONLY=1 ;;
        *) echo "unknown flag: $arg" >&2; exit 2 ;;
    esac
done

echo "== compileall (syntax, all of src/ + tests/ + benchmarks/ + examples/)"
python -m compileall -q src tests benchmarks examples

if python -c "import pyflakes" 2>/dev/null; then
    echo "== pyflakes src/"
    python -m pyflakes src
elif [[ "$STRICT" == 1 ]]; then
    echo "== pyflakes not installed — FAILING (--strict requires the lint gate)" >&2
    exit 1
else
    echo "== pyflakes not installed; skipping (compileall still ran; --strict would fail here)"
fi

if [[ "$LINT_ONLY" == 1 ]]; then
    exit 0
fi

echo "== qlint (HLO invariant sweep vs results/qlint_baseline.json)"
PYTHONPATH=src python -m repro.launch.qlint --baseline results/qlint_baseline.json --fail-on-gone

echo "== autotune sweep smoke (committed CI-shape cache complete, zero tuning probes)"
PYTHONPATH=src python -m repro.launch.autotune_sweep --smoke --cache results/autotune/cpu.json

echo "== serving daemon smoke (wall-clock streamed request, clean shutdown)"
PYTHONPATH=src python -m repro.launch.daemon --arch qwen1.5-0.5b --reduced \
    --smoke --no-quant --max-new 4 --max-batch 2 --timeout 60

echo "== crash-recovery smoke (journaled daemon under crash@decode: supervised restart, replay, exact reconcile)"
PYTHONPATH=src python -m repro.launch.daemon --arch qwen1.5-0.5b --reduced \
    --recovery-smoke --no-quant --requests 3 --max-new 4 --max-batch 2 --timeout 120

echo "== fast suite (./test.sh -m 'not slow')"
exec ./test.sh -m "not slow"
